"""End-to-end LM training driver: data pipeline -> sharded model -> AdamW ->
fault-tolerant loop with checkpointing.

Default preset trains a ~25M-param model long enough to see the loss fall on
CPU; `--preset 100m --steps 300` is the paper-brief configuration (suitable
for a real accelerator or a patient CPU).

    PYTHONPATH=src python examples/train_lm.py [--steps 120] [--preset small]
"""
import argparse
import dataclasses

import jax

from repro.configs.base import AttnCfg, ModelConfig
from repro.models import build_model, count_params
from repro.train.data import DataConfig, SyntheticDataset
from repro.train.elastic import SimulatedFailures
from repro.train.loop import LoopConfig, train_loop
from repro.train.optimizer import adamw, cosine_schedule
from repro.train.train_step import make_train_step

PRESETS = {
    "small": dict(n_layers=4, d_model=384, d_ff=1536, vocab=4096,
                  heads=6, kv=2, seq=128, batch=8),
    "100m": dict(n_layers=12, d_model=768, d_ff=3072, vocab=16384,
                 heads=12, kv=4, seq=512, batch=8),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--inject-failure", action="store_true",
                    help="kill the loop mid-run to demo checkpoint restart")
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = ModelConfig(
        name=f"lm-{args.preset}", family="dense",
        n_layers=p["n_layers"], d_model=p["d_model"], d_ff=p["d_ff"],
        vocab=p["vocab"],
        attn=AttnCfg(n_heads=p["heads"], n_kv=p["kv"],
                     head_dim=p["d_model"] // p["heads"]),
        vocab_pad_to=128, remat="none",
    )
    model = build_model(cfg)
    params, roles = model.init(jax.random.PRNGKey(0))
    print(f"model: {count_params(cfg)/1e6:.1f}M params")

    opt = adamw(cosine_schedule(3e-3, warmup=20, total=args.steps),
                weight_decay=0.01, grad_clip=1.0)
    step = jax.jit(make_train_step(model, opt, microbatches=2))
    data = SyntheticDataset(DataConfig(vocab=cfg.vocab, seq=p["seq"],
                                       global_batch=p["batch"]))
    failures = SimulatedFailures(fail_at=(args.steps // 2,)) \
        if args.inject_failure else None
    res = train_loop(step, params, opt.init(params), data,
                     LoopConfig(total_steps=args.steps, checkpoint_every=40,
                                checkpoint_dir=args.ckpt_dir, log_every=10),
                     failures=failures)
    print(f"done: loss {res['losses'][0]:.3f} -> {res['losses'][-1]:.3f} "
          f"({res['restarts']} restarts, {res['stragglers']} stragglers)")


if __name__ == "__main__":
    main()
