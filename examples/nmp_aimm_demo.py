"""Paper reproduction demo: Fig. 6-style table for one or all apps —
techniques {BNMP, LDB, PEI} x mappers {Baseline, TOM, AIMM}.

    PYTHONPATH=src python examples/nmp_aimm_demo.py [--app SPMV | --all]
"""
import argparse

from repro.nmp import NMPConfig, make_trace, run_episode, run_program
from repro.nmp.stats import summarize
from repro.nmp.traces import APPS


def row(app, cfg, n_ops, episodes):
    tr = make_trace(app, n_ops=n_ops)
    out = {}
    for tech in ("bnmp", "ldb", "pei"):
        base = summarize(run_episode(tr, cfg, tech, "none"))["cycles"]
        tom = summarize(run_episode(tr, cfg, tech, "tom"))["cycles"]
        aimm = summarize(run_program(tr, cfg, tech, "aimm",
                                     episodes=episodes)[-1])["cycles"]
        out[tech] = (1.0, tom / base, aimm / base)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="PR")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--n-ops", type=int, default=16384)
    ap.add_argument("--episodes", type=int, default=5)
    args = ap.parse_args()

    cfg = NMPConfig()
    apps = APPS if args.all else [args.app]
    print(f"{'app':6s} {'tech':5s} {'B':>6s} {'TOM':>6s} {'AIMM':>6s}   "
          "(execution time normalized to each technique's baseline)")
    for app in apps:
        r = row(app, cfg, args.n_ops, args.episodes)
        for tech, (b, t, a) in r.items():
            print(f"{app:6s} {tech:5s} {b:6.2f} {t:6.2f} {a:6.2f}")


if __name__ == "__main__":
    main()
