"""Paper reproduction demo: Fig. 6-style table for one or all apps —
techniques {BNMP, LDB, PEI} x mappers {Baseline, TOM, AIMM}.

The whole table is one batched sweep (`sweep.run_grid`): every
(app, technique, mapper) cell is a lane of a single compiled program instead
of a serial run per cell.

    PYTHONPATH=src python examples/nmp_aimm_demo.py [--app SPMV | --all]
"""
import argparse

from repro.nmp import NMPConfig
from repro.nmp.scenarios import single_program_grid
from repro.nmp.sweep import run_grid
from repro.nmp.traces import APPS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="PR")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--n-ops", type=int, default=16384)
    ap.add_argument("--episodes", type=int, default=5)
    args = ap.parse_args()

    cfg = NMPConfig()
    apps = APPS if args.all else [args.app]
    grid = single_program_grid(apps=apps,
                               techniques=("bnmp", "ldb", "pei"),
                               mappers=("none", "tom", "aimm"),
                               n_ops=args.n_ops,
                               aimm_episodes=args.episodes)
    res = run_grid(grid, cfg)
    cell = {sc.name: res.episode_summary(i)["cycles"]
            for i, sc in enumerate(grid)}

    print(f"{'app':6s} {'tech':5s} {'B':>6s} {'TOM':>6s} {'AIMM':>6s}   "
          "(execution time normalized to each technique's baseline; "
          f"{len(grid)} lanes in {res.wall_s:.1f}s batched)")
    for app in apps:
        for tech in ("bnmp", "ldb", "pei"):
            base = cell[f"{app}/{tech}/none/s0"]
            tom = cell[f"{app}/{tech}/tom/s0"]
            aimm = cell[f"{app}/{tech}/aimm/s0"]
            print(f"{app:6s} {tech:5s} {1.0:6.2f} {tom / base:6.2f} "
                  f"{aimm / base:6.2f}")


if __name__ == "__main__":
    main()
