"""Beyond-paper: the AIMM agent searching TPU sharding/mapping knobs.

The same continual dueling-DQN that remaps NMP pages drives microbatching,
remat policy, FSDP, int8-optimizer and expert-parallel decisions for any
assigned architecture, rewarded by the analytic roofline step time — and is
validated against exhaustive search over the knob lattice.

    PYTHONPATH=src python examples/sharding_search.py --arch qwen3-32b
"""
import argparse

from repro.configs import ARCHS, SHAPES, get_config
from repro.core.sharding_mapper import Knobs, exhaustive_best, search


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="jamba-1.5-large-398b", choices=ARCHS)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    res = search(cfg, shape, steps=args.steps)
    gt, gt_t = exhaustive_best(cfg, shape)

    fmt = lambda t: "OOM" if t == float("inf") else f"{t*1e3:.1f} ms"
    print(f"arch={args.arch} shape={args.shape} mesh=16x16 (256 chips)")
    print(f"  start mapping : {Knobs()}  step={fmt(res.baseline_step_s)}")
    print(f"  RL-found      : {res.best}  step={fmt(res.best_step_s)}")
    print(f"  exhaustive    : {gt}  step={fmt(gt_t)}")
    gap = (res.best_step_s / gt_t - 1) * 100 if gt_t > 0 else 0.0
    print(f"  RL vs optimum : {gap:+.1f}%")
    visited = len({k for k, _ in res.trajectory})
    print(f"  ({args.steps} invocations, {visited} distinct mappings visited; "
          f"exhaustive sweep is {6*3*2*2*2})")


if __name__ == "__main__":
    main()
