"""Beyond-paper: the AIMM agent searching TPU sharding/mapping knobs.

The same continual dueling-DQN that remaps NMP pages drives microbatching,
remat policy, FSDP, int8-optimizer and expert-parallel decisions for any
assigned architecture, rewarded by the analytic roofline step time — and is
validated against exhaustive search over the knob lattice.

    PYTHONPATH=src python examples/sharding_search.py --arch qwen3-32b

Like the NMP sweep engine, the example is grid-shaped: `--arch all` (or a
comma list) sweeps the scenario grid of architectures x seeds and prints one
row per cell with the RL-vs-exhaustive optimality gap.
"""
import argparse

from repro.configs import ARCHS, SHAPES, get_config
from repro.core.sharding_mapper import Knobs, exhaustive_best, search


def _fmt(t):
    return "OOM" if t == float("inf") else f"{t*1e3:.1f} ms"


def run_one(arch: str, shape_name: str, steps: int, seed: int, verbose: bool):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    res = search(cfg, shape, steps=steps, seed=seed)
    gt, gt_t = exhaustive_best(cfg, shape)
    gap = (res.best_step_s / gt_t - 1) * 100 if gt_t > 0 else 0.0
    if verbose:
        print(f"arch={arch} shape={shape_name} mesh=16x16 (256 chips)")
        print(f"  start mapping : {Knobs()}  step={_fmt(res.baseline_step_s)}")
        print(f"  RL-found      : {res.best}  step={_fmt(res.best_step_s)}")
        print(f"  exhaustive    : {gt}  step={_fmt(gt_t)}")
        print(f"  RL vs optimum : {gap:+.1f}%")
        visited = len({k for k, _ in res.trajectory})
        print(f"  ({steps} invocations, {visited} distinct mappings visited; "
              f"exhaustive sweep is {6*3*2*2*2})")
    return res, gt_t, gap


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="jamba-1.5-large-398b",
                    help="architecture, comma list, or 'all'")
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seeds", type=int, default=1,
                    help="seeds per architecture in sweep mode")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    for a in archs:
        assert a in ARCHS, f"unknown arch {a!r} (choices: {', '.join(ARCHS)})"

    if len(archs) == 1 and args.seeds == 1:
        run_one(archs[0], args.shape, args.steps, seed=0, verbose=True)
        return

    print(f"{'arch':28s} {'seed':>4s} {'RL step':>10s} {'optimum':>10s} "
          f"{'gap':>7s}")
    for arch in archs:
        for seed in range(args.seeds):
            res, gt_t, gap = run_one(arch, args.shape, args.steps, seed,
                                     verbose=False)
            print(f"{arch:28s} {seed:4d} {_fmt(res.best_step_s):>10s} "
                  f"{_fmt(gt_t):>10s} {gap:+6.1f}%")


if __name__ == "__main__":
    main()
