"""Quickstart: AIMM improving an NMP workload in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py [--app SPMV]
"""
import argparse

from repro.nmp import NMPConfig, make_trace, run_episode, run_program
from repro.nmp.stats import summarize


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="PR", help="BP LUD KM MAC PR RBM RD SC SPMV")
    ap.add_argument("--episodes", type=int, default=5)
    args = ap.parse_args()

    cfg = NMPConfig()                       # paper Table 1: 4x4 cube mesh
    trace = make_trace(args.app, n_ops=16384)

    base = summarize(run_episode(trace, cfg, technique="bnmp", mapper="none"))
    print(f"BNMP baseline : OPC={base['opc']:.3f} cycles={base['cycles']:.0f}")

    results = run_program(trace, cfg, technique="bnmp", mapper="aimm",
                          episodes=args.episodes, seed=0)
    for i, r in enumerate(results):
        s = summarize(r)
        print(f"AIMM episode {i}: OPC={s['opc']:.3f} "
              f"speedup={base['cycles'] / s['cycles']:.2f}x "
              f"migrations={s['migrations']:.0f} "
              f"util={s['compute_util']:.2f}")
    print("(the dueling-DQN persists across episodes — the paper's "
          "continual-learning protocol)")


if __name__ == "__main__":
    main()
