"""Interpret-mode Pallas parity suite (`make test-pallas`).

Runs both Pallas kernel families on CPU via `interpret=True` and pins them
against the pure-jnp paths and the engine goldens:

  * the fused epoch kernel (repro.kernels.epoch_fused) — the engine golden
    table re-run under REPRO_EPOCH_BACKEND=pallas_interpret must reproduce
    the pinned values bit-for-bit (the kernel's reductions are exact-integer
    f32 sums, so any reduction order gives the same bits — see
    kernels/epoch_fused/kernel.py), across minimal and full BodyFlags
    (bnmp/none compiles the PEI/TOM/agent machinery out; pei/aimm and
    pei/tom light all of it up);
  * the batched sweep with S==1 and S>1 folded seed axes, seed-invariant
    sharing on and off — every grid cell bit-identical to the jnp backend;
  * the ops-level dispatchers (shared/route/fused/TOM stages) on a real
    trace window;
  * the dueling-qnet forward kernel in interpret mode vs its jnp oracle;
  * the backend knobs' fail-fast validation (REPRO_EPOCH_BACKEND,
    REPRO_SWEEP_LAND, REPRO_STORE_STAGING) and the auto->jnp CPU default.

The engine reads the knob through `BodyFlags.epoch_backend` — a static jit
argument — so monkeypatching the env var between calls genuinely selects a
different compiled program instead of a stale resident one.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.epoch_fused import EPOCH_BACKENDS, resolve_backend
from repro.kernels.epoch_fused import ops as epoch_ops
from repro.nmp import NMPConfig, make_trace
from repro.nmp.engine import pei_hot_index, run_episode
from repro.nmp.stats import summarize

from tests.test_engine_golden import GOLDEN

CFG = NMPConfig()

# Subset of the golden table covering every technique, both baseline mappers
# (incl. the SPMV trace long enough for TOM to profile + commit) and the
# scripted-AIMM remap path — i.e. minimal BodyFlags (bnmp/none: PEI, TOM and
# the agent all compiled out) through full ones (pei/aimm, pei/tom).
PARITY_KEYS = sorted(k for k in GOLDEN
                     if k[0] == "KM" or k[2] == "pei" or k[3] == "aimm")


def _metrics_equal(a, b) -> bool:
    return (set(a.metrics) == set(b.metrics)
            and all(np.array_equal(np.asarray(a.metrics[k]),
                                   np.asarray(b.metrics[k]))
                    for k in a.metrics))


# ---------------------------------------------------------------------------
# fused epoch kernel vs engine goldens (serial path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("key", PARITY_KEYS,
                         ids=lambda k: "/".join(map(str, k)))
def test_fused_kernel_reproduces_engine_goldens(key, monkeypatch):
    monkeypatch.setenv(epoch_ops.ENV_KNOB, "pallas_interpret")
    app, n_ops, tech, mapper, forced = key
    tr = make_trace(app, n_ops=n_ops)
    s = summarize(run_episode(tr, CFG, tech, mapper, seed=2,
                              forced_action=forced))
    assert (s["cycles"], s["ops"], s["opc"]) == GOLDEN[key], (key, s)


# ---------------------------------------------------------------------------
# batched sweep: S==1 and S>1, seed sharing on/off
# ---------------------------------------------------------------------------

def _grid():
    from repro.nmp.scenarios import single_program_grid
    grid = single_program_grid(apps=("KM",), mappers=("aimm",), n_ops=384,
                               seeds=(0, 1, 2), aimm_episodes=2)
    grid += single_program_grid(apps=("KM",), techniques=("pei",),
                                mappers=("none", "tom"), n_ops=384, seeds=(0,))
    return grid


@pytest.mark.parametrize("share", ["on", "off"])
def test_sweep_grid_parity_seed_axes(share, monkeypatch):
    """The folded-seed grid (S>1 AIMM group + S==1 baseline lanes) must be
    bit-identical between the jnp backend and the interpret-mode kernel, with
    seed-invariant sharing both on (split shared/route kernel calls) and off
    (one fully fused call per cell)."""
    from repro.nmp.sweep import run_grid
    grid = _grid()
    monkeypatch.setenv("REPRO_SEED_SHARE", share)
    monkeypatch.setenv(epoch_ops.ENV_KNOB, "jnp")
    ref = run_grid(grid)
    monkeypatch.setenv(epoch_ops.ENV_KNOB, "pallas_interpret")
    got = run_grid(grid)
    assert _metrics_equal(ref, got)


# ---------------------------------------------------------------------------
# ops-level stage parity on a real trace window
# ---------------------------------------------------------------------------

def _window():
    from repro.nmp.engine import _init_env, phase_ring_len, state_spec_for
    from repro.nmp.paging import default_alloc
    from repro.nmp.topology import get_topology
    tr = make_trace("KM", n_ops=384)
    topo = get_topology(CFG)
    spec = state_spec_for(CFG)
    env = _init_env(default_alloc(tr.n_pages, CFG), CFG, spec, 2,
                    phase_ring_len(tr, CFG))
    W = CFG.w_max
    sl = slice(0, W)
    dest = jnp.asarray(tr.dest[sl])
    src1 = jnp.asarray(tr.src1[sl])
    src2 = jnp.asarray(tr.src2[sl])
    valid = jnp.ones((W,), jnp.float32)
    return tr, topo, env, dest, src1, src2, valid


@pytest.mark.parametrize("pei_k", [0, 8])
def test_stage_dispatchers_bit_identical(pei_k):
    tr, topo, env, dest, src1, src2, valid = _window()
    kw = dict(pei_k=pei_k, aimm=True)
    sp_ref = epoch_ops.shared_parts(
        dest, src1, src2, valid, env.epochs, env.rb_stamp,
        env.page_access_ema, tr.n_pages, jnp.asarray(pei_hot_index(tr.n_pages, CFG), jnp.int32),
        backend="jnp", **kw)
    sp_ker = epoch_ops.shared_parts(
        dest, src1, src2, valid, env.epochs, env.rb_stamp,
        env.page_access_ema, tr.n_pages, jnp.asarray(pei_hot_index(tr.n_pages, CFG), jnp.int32),
        backend="pallas_interpret", **kw)
    for name, a, b in zip(sp_ref._fields, sp_ref, sp_ker):
        if a is None:
            assert b is None
            continue
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)

    from repro.nmp.baselines import TECHNIQUES
    from repro.nmp.paging import default_alloc
    eff = jnp.asarray(default_alloc(tr.n_pages, CFG), jnp.int32)  # page->cube
    tech = jnp.asarray(TECHNIQUES.index("pei" if pei_k else "bnmp"), jnp.int32)
    rp_ref = epoch_ops.route_parts(
        dest, src1, src2, valid, sp_ref.rb_winner, sp_ref.pei_hot1,
        sp_ref.pei_hot2, eff, env.compute_remap, tech,
        jnp.asarray(True), env.pending_mig_loads, topo,
        n_mcs=CFG.n_mcs, packet_flits=CFG.packet_flits, backend="jnp", **kw)
    rp_ker = epoch_ops.route_parts(
        dest, src1, src2, valid, sp_ref.rb_winner, sp_ref.pei_hot1,
        sp_ref.pei_hot2, eff, env.compute_remap, tech,
        jnp.asarray(True), env.pending_mig_loads, topo,
        n_mcs=CFG.n_mcs, packet_flits=CFG.packet_flits,
        backend="pallas_interpret", **kw)
    for name, a, b in zip(rp_ref._fields, rp_ref, rp_ker):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)


def test_tom_scores_bit_identical():
    _tr, _topo, _env, dest, src1, src2, valid = _window()
    cands = jnp.stack([jnp.arange(CFG.n_cubes, dtype=jnp.int32),
                       jnp.roll(jnp.arange(CFG.n_cubes, dtype=jnp.int32), 1),
                       jnp.flip(jnp.arange(CFG.n_cubes, dtype=jnp.int32))])
    ref = epoch_ops.tom_scores(dest, src1, src2, valid, cands,
                               n_cubes=CFG.n_cubes, backend="jnp")
    ker = epoch_ops.tom_scores(dest, src1, src2, valid, cands,
                               n_cubes=CFG.n_cubes,
                               backend="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(ker))


# ---------------------------------------------------------------------------
# dueling qnet interpret-mode parity
# ---------------------------------------------------------------------------

def test_qnet_interpret_matches_jnp_oracle():
    from repro.kernels.dueling_qnet.ops import qnet_forward
    from repro.kernels.dueling_qnet.ref import dueling_qnet_ref
    rng = np.random.default_rng(0)
    S, H, A, B = 106, 128, 8, 37
    p = {k: jnp.asarray(rng.normal(scale=0.5, size=s).astype(np.float32))
         for k, s in {"w0": (S, H), "b0": (H,), "w1": (H, H), "b1": (H,),
                      "w_v": (H, 1), "b_v": (1,), "w_a": (H, A),
                      "b_a": (A,)}.items()}
    x = jnp.asarray(rng.normal(size=(B, S)).astype(np.float32))
    got = qnet_forward(p, x, interpret=True)        # the Pallas kernel body
    want = dueling_qnet_ref(x, p["w0"], p["b0"], p["w1"], p["b1"],
                            p["w_v"], p["b_v"], p["w_a"], p["b_a"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# knob validation + resolution
# ---------------------------------------------------------------------------

def test_epoch_backend_knob_validates(monkeypatch):
    monkeypatch.setenv(epoch_ops.ENV_KNOB, "banana")
    with pytest.raises(ValueError, match="REPRO_EPOCH_BACKEND.*banana"):
        resolve_backend()
    with pytest.raises(ValueError, match="cuda"):
        resolve_backend("cuda")
    for mode in EPOCH_BACKENDS:
        monkeypatch.setenv(epoch_ops.ENV_KNOB, mode)
        assert resolve_backend() in ("jnp", "pallas", "pallas_interpret")


def test_epoch_backend_auto_is_jnp_on_cpu(monkeypatch):
    import jax
    monkeypatch.delenv(epoch_ops.ENV_KNOB, raising=False)
    expect = "pallas" if jax.default_backend() == "tpu" else "jnp"
    assert resolve_backend() == expect
    assert resolve_backend("auto") == expect


def test_sweep_knobs_validate(monkeypatch):
    from repro.nmp import sweep
    monkeypatch.setenv(sweep.LAND_KNOB, "later")
    with pytest.raises(ValueError, match="REPRO_SWEEP_LAND.*later"):
        sweep.land_mode()
    monkeypatch.setenv(sweep.LAND_KNOB, "sync")
    assert sweep.land_mode() == "sync"
    monkeypatch.delenv(sweep.LAND_KNOB, raising=False)
    assert sweep.land_mode() == "async"

    monkeypatch.setenv(sweep.STAGING_KNOB, "maybe")
    with pytest.raises(ValueError, match="REPRO_STORE_STAGING.*maybe"):
        sweep.staging_enabled()
    monkeypatch.setenv(sweep.STAGING_KNOB, "off")
    assert sweep.staging_enabled() is False
    monkeypatch.delenv(sweep.STAGING_KNOB, raising=False)
    assert sweep.staging_enabled() is True


# ---------------------------------------------------------------------------
# staging + async landing equivalence (the PR's dispatch-side satellites)
# ---------------------------------------------------------------------------

def test_async_land_and_staging_bit_identical(monkeypatch):
    """Chained lineage run_grid calls under the new defaults (async landing,
    staging buffers) must produce bit-identical metrics AND final store
    snapshots to the historical sync/per-cell path."""
    import jax

    from repro.nmp import sweep
    from repro.nmp.scenarios import single_program_grid
    grid = single_program_grid(apps=("KM", "PR"), mappers=("aimm",),
                               n_ops=256, seeds=(0, 1), aimm_episodes=2)
    grid += single_program_grid(apps=("KM",), mappers=("none",), n_ops=256,
                                seeds=(0,))
    grid = [dataclasses.replace(sc, lineage=f"lin{i}")
            if sc.mapper == "aimm" else sc for i, sc in enumerate(grid)]

    def chain():
        r1 = sweep.run_grid(grid)
        return r1, sweep.run_grid(grid, store=r1.store)

    monkeypatch.setenv(sweep.LAND_KNOB, "sync")
    monkeypatch.setenv(sweep.STAGING_KNOB, "off")
    a1, a2 = chain()
    monkeypatch.setenv(sweep.LAND_KNOB, "async")
    monkeypatch.setenv(sweep.STAGING_KNOB, "on")
    b1, b2 = chain()
    assert _metrics_equal(a1, b1) and _metrics_equal(a2, b2)
    sa, sb = a2.store, b2.store
    assert sa.tags == sb.tags
    for tag in sa.tags:
        for x, y in zip(jax.tree.leaves(sa.get(tag)),
                        jax.tree.leaves(sb.get(tag))):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
