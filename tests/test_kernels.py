"""Per-kernel validation: shape/dtype sweeps + hypothesis, allclose vs the
pure-jnp oracles (interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.dueling_qnet.ops import qnet_forward
from repro.kernels.dueling_qnet.ref import dueling_qnet_ref
from repro.kernels.flash_attention.ops import gqa_flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_ref


def _rand(key, *shape, dtype=jnp.float32, scale=0.5):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale
            ).astype(dtype)


# ---------------------------------------------------------------------------
# dueling qnet
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch", [1, 37, 128, 300])
@pytest.mark.parametrize("state_dim", [64, 106, 256])
def test_qnet_shapes(batch, state_dim):
    H, A = 128, 8
    params = {"w0": _rand(0, state_dim, H), "b0": _rand(1, H),
              "w1": _rand(2, H, H), "b1": _rand(3, H),
              "w_v": _rand(4, H, 1), "b_v": _rand(5, 1),
              "w_a": _rand(6, H, A), "b_a": _rand(7, A)}
    x = _rand(8, batch, state_dim)
    got = qnet_forward(params, x)
    want = dueling_qnet_ref(x, params["w0"], params["b0"], params["w1"],
                            params["b1"], params["w_v"], params["b_v"],
                            params["w_a"], params["b_a"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@settings(deadline=None, max_examples=10)
@given(st.integers(1, 64), st.integers(2, 12))
def test_qnet_hypothesis(batch, actions):
    S, H = 32, 64
    params = {"w0": _rand(10, S, H), "b0": _rand(11, H),
              "w1": _rand(12, H, H), "b1": _rand(13, H),
              "w_v": _rand(14, H, 1), "b_v": _rand(15, 1),
              "w_a": _rand(16, H, actions), "b_a": _rand(17, actions)}
    x = _rand(18, batch, S)
    got = qnet_forward(params, x)
    want = dueling_qnet_ref(x, params["w0"], params["b0"], params["w1"],
                            params["b1"], params["w_v"], params["b_v"],
                            params["w_a"], params["b_a"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S", [128, 256, 384])
@pytest.mark.parametrize("hd", [64, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, hd, dtype):
    B, H, K = 2, 4, 2
    q = _rand(0, B, S, H, hd, dtype=dtype)
    k = _rand(1, B, S, K, hd, dtype=dtype)
    v = _rand(2, B, S, K, hd, dtype=dtype)
    got = gqa_flash_attention(q, k, v, causal=True)
    kk = jnp.repeat(k, H // K, axis=2)
    vv = jnp.repeat(v, H // K, axis=2)
    want = attention_ref(q.transpose(0, 2, 1, 3), kk.transpose(0, 2, 1, 3),
                         vv.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_noncausal():
    B, S, H, hd = 1, 256, 2, 64
    q = _rand(3, B, S, H, hd)
    k = _rand(4, B, S, H, hd)
    v = _rand(5, B, S, H, hd)
    got = gqa_flash_attention(q, k, v, causal=False)
    want = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3),
                         causal=False).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("L,chunk", [(64, 32), (128, 128), (256, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_sweep(L, chunk, dtype):
    B, H, P, N = 2, 4, 16, 8
    x = _rand(0, B, L, H, P, dtype=dtype)
    b = _rand(1, B, L, N, dtype=dtype)
    c = _rand(2, B, L, N, dtype=dtype)
    dt = jnp.abs(_rand(3, B, L, H)) * 0.1
    a = -jnp.abs(_rand(4, H)) - 0.1
    got = ssd(x, b, c, dt, a, chunk=chunk)
    want = ssd_ref(x, b, c, dt, a)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@settings(deadline=None, max_examples=8)
@given(st.integers(1, 3), st.integers(1, 4))
def test_ssd_hypothesis(B, nheads):
    L, P, N, chunk = 64, 8, 4, 32
    x = _rand(20, B, L, nheads, P)
    b = _rand(21, B, L, N)
    c = _rand(22, B, L, N)
    dt = jnp.abs(_rand(23, B, L, nheads)) * 0.2
    a = -jnp.abs(_rand(24, nheads)) - 0.05
    got = ssd(x, b, c, dt, a, chunk=chunk)
    want = ssd_ref(x, b, c, dt, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_ssd_head_group_split():
    """Force the VMEM head-group split path."""
    import repro.kernels.ssd_scan.ops as ops
    B, L, H, P, N = 1, 64, 8, 8, 4
    x = _rand(30, B, L, H, P)
    b = _rand(31, B, L, N)
    c = _rand(32, B, L, N)
    dt = jnp.abs(_rand(33, B, L, H)) * 0.1
    a = -jnp.abs(_rand(34, H)) - 0.1
    old = ops.VMEM_BUDGET
    try:
        ops.VMEM_BUDGET = 64 * 64 * 4 * 2       # forces hg < H
        got = ops.ssd(x, b, c, dt, a, chunk=64)
    finally:
        ops.VMEM_BUDGET = old
    want = ssd_ref(x, b, c, dt, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_model_attention_matches_kernel():
    """The model's scan-based chunked attention and the Pallas kernel agree."""
    from repro.models.attention import attend_chunked
    B, S, H, hd = 1, 1024, 2, 64
    q = _rand(40, B, S, H, hd)
    k = _rand(41, B, S, H, hd)
    v = _rand(42, B, S, H, hd)
    a = attend_chunked(q, k, v, "causal", 0, hd ** -0.5)
    b_ = gqa_flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-4,
                               atol=2e-4)
