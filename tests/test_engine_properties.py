"""Hypothesis property tests on system invariants of the NMP engine."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.nmp import NMPConfig, run_episode
from repro.nmp.stats import summarize
from repro.nmp.traces import Trace

CFG = NMPConfig()


def _random_trace(seed: int, n_ops: int, n_pages: int) -> Trace:
    rng = np.random.default_rng(seed)
    d = rng.integers(0, n_pages, n_ops).astype(np.int32)
    s1 = rng.integers(0, n_pages, n_ops).astype(np.int32)
    s2 = rng.integers(0, n_pages, n_ops).astype(np.int32)
    rw = np.zeros(n_pages, bool)
    rw[np.unique(d)] = True
    return Trace("rand", d, s1, s2, n_pages, rw, np.zeros_like(d),
                 iter_ops=n_ops // 2)


@settings(deadline=None, max_examples=6)
@given(st.integers(0, 10_000), st.sampled_from([256, 384, 512]),
       st.sampled_from(["bnmp", "ldb", "pei"]))
def test_op_conservation_any_trace(seed, n_ops, technique):
    """Every op of any trace is processed exactly once; all derived stats stay
    in their physical ranges."""
    tr = _random_trace(seed, n_ops, 128)
    s = summarize(run_episode(tr, CFG, technique=technique, mapper="none"))
    assert s["ops"] == n_ops
    assert s["cycles"] > 0
    assert 0 <= s["compute_util"] <= 1
    assert s["mean_hops"] >= 0
    assert s["energy_nj"] > 0


@settings(deadline=None, max_examples=4)
@given(st.integers(0, 10_000), st.integers(0, 5))
def test_aimm_page_table_stays_valid(seed, action):
    """Whatever action the agent (here scripted) takes, the page table maps
    every page to a real cube and migrated fractions stay in [0, 1]."""
    tr = _random_trace(seed, 512, 96)
    res = run_episode(tr, CFG, technique="bnmp", mapper="aimm",
                      forced_action=action, seed=seed)
    p2c = np.asarray(res.env.page_to_cube)
    assert (p2c >= 0).all() and (p2c < CFG.n_cubes).all()
    cr = np.asarray(res.env.compute_remap)
    assert ((cr >= -1) & (cr <= CFG.n_cubes)).all()
    s = summarize(res)
    assert 0 <= s["frac_pages_migrated"] <= 1
    assert 0 <= s["frac_access_migrated"] <= 1
    assert s["ops"] == 512
