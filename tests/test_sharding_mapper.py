"""Beyond-paper integration: the AIMM agent over TPU mapping knobs."""
import pytest

from repro.configs import SHAPES, get_config
from repro.core.sharding_mapper import (CostModel, Knobs, exhaustive_best,
                                        search)


def test_cost_model_feasibility():
    cfg = get_config("jamba-1.5-large-398b")
    cm = CostModel(cfg, SHAPES["train_4k"])
    naive = Knobs(microbatches=8, remat="full", fsdp=False, quant_opt=False)
    assert cm.step_s(naive) == float("inf")       # 398B can't fit TP-only
    fitted = Knobs(microbatches=16, remat="full", fsdp=True, quant_opt=True)
    assert cm.step_s(fitted) < float("inf")


def test_tp_in_expert_penalty_measured():
    """§Perf A4: capacity-dispatch + TP-in-expert is pathological; the
    calibrated model must prefer EP for the MoE archs."""
    cfg = get_config("deepseek-moe-16b")
    cm = CostModel(cfg, SHAPES["train_4k"])
    ep = Knobs(moe_ep=True)
    tp = Knobs(moe_ep=False)
    assert cm.collective_s(tp) > 3 * cm.collective_s(ep)


@pytest.mark.slow
def test_rl_search_beats_infeasible_start():
    cfg = get_config("jamba-1.5-large-398b")
    res = search(cfg, SHAPES["train_4k"], steps=150, seed=0)
    assert res.baseline_step_s == float("inf")
    assert res.best_step_s < float("inf")         # escaped the OOM plateau
    assert res.best.fsdp and res.best.quant_opt


@pytest.mark.slow
def test_rl_search_near_optimal_dense():
    cfg = get_config("qwen3-32b")
    gt, gt_t = exhaustive_best(cfg, SHAPES["train_4k"])
    res = search(cfg, SHAPES["train_4k"], steps=250, seed=0)
    assert res.best_step_s <= gt_t * 1.3, (res.best, gt)


def test_exhaustive_respects_hbm():
    from repro.core.sharding_mapper import HBM_PER_CHIP
    for arch in ("qwen3-32b", "mixtral-8x22b"):
        cfg = get_config(arch)
        cm = CostModel(cfg, SHAPES["train_4k"])
        best, t = exhaustive_best(cfg, SHAPES["train_4k"])
        assert cm.hbm_per_chip(best) <= HBM_PER_CHIP
