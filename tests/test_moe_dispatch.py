"""MoE dispatch properties: grouped == global under ample capacity; dropping
bounded by capacity; gate weights sum to 1 over kept slots."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoECfg
from repro.models.moe import _capacity, init_moe, moe_ffn


def _setup(cf=8.0, groups=1, pre=False):
    cfg = MoECfg(n_routed=8, top_k=2, d_expert=64, capacity_factor=cf,
                 dispatch_groups=groups, router_pre_softmax=pre)
    params, _ = init_moe(jax.random.PRNGKey(0), 32, cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 16, 32)),
                    jnp.bfloat16)
    return cfg, params, x


def test_grouped_equals_global_with_ample_capacity():
    cfg1, params, x = _setup(groups=1)
    cfg4 = dataclasses.replace(cfg1, dispatch_groups=4)
    y1, a1 = moe_ffn(params, x, cfg1)
    y4, a4 = moe_ffn(params, x, cfg4)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y4, np.float32), atol=1e-3)
    assert float(a1["drop_frac"]) == 0.0
    assert float(a4["drop_frac"]) == 0.0


@pytest.mark.parametrize("pre", [False, True])
def test_tight_capacity_drops_bounded(pre):
    cfg, params, x = _setup(cf=0.5, pre=pre)
    y, aux = moe_ffn(params, x, cfg)
    assert 0.0 < float(aux["drop_frac"]) < 1.0
    assert bool(jnp.isfinite(y.astype(jnp.float32)).all())


def test_capacity_rounding():
    cfg = MoECfg(n_routed=8, top_k=2, d_expert=16)
    assert _capacity(64, cfg) % 8 == 0
    assert _capacity(8, cfg) >= 8


def test_shared_experts_add_signal():
    cfg = MoECfg(n_routed=4, top_k=1, d_expert=32, n_shared=2)
    params, _ = init_moe(jax.random.PRNGKey(1), 32, cfg)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((2, 8, 32)),
                    jnp.bfloat16)
    y, _ = moe_ffn(params, x, cfg)
    # zero the shared weights -> output must change
    p2 = dict(params, ws_down=jnp.zeros_like(params["ws_down"]))
    y2, _ = moe_ffn(p2, x, cfg)
    assert float(jnp.abs(y.astype(jnp.float32)
                         - y2.astype(jnp.float32)).max()) > 0
