"""Sharding policy correctness: every produced spec divides its tensor dims,
for every architecture on both production meshes (via AbstractMesh — no
devices needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.models import build_model
from repro.models.model import abstract_init
from repro.sharding import policies


def _mesh(multi):
    # jax >= 0.4.36 AbstractMesh takes ((name, size), ...) pairs
    if multi:
        return AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
    return AbstractMesh((("data", 16), ("model", 16)))


def _axis_size(mesh, axis):
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _check(specs, shapes, mesh, where):
    flat_s, _ = jax.tree_util.tree_flatten(specs)
    flat_h, _ = jax.tree_util.tree_flatten(shapes)
    assert len(flat_s) == len(flat_h), where
    for sh, sp in zip(flat_h, flat_s):
        spec = sp.spec
        for d, ax in zip(sh.shape, tuple(spec) + (None,) * 10):
            sz = _axis_size(mesh, ax)
            assert d % sz == 0, (where, sh.shape, spec)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("multi", [False, True])
def test_param_and_batch_specs_divide(arch, multi):
    mesh = _mesh(multi)
    cfg = get_config(arch)
    model = build_model(cfg)
    pshapes, roles = abstract_init(model)
    pspecs = policies.param_specs(roles, pshapes, cfg, mesh)
    _check(pspecs, pshapes, mesh, f"{arch} params")
    gspecs = policies.zero_shard_specs(pspecs, pshapes, mesh, cfg)
    _check(gspecs, pshapes, mesh, f"{arch} grads")

    for sname, shape in SHAPES.items():
        ok, _ = shape_applicable(cfg, shape)
        if not ok:
            continue
        bsds = model.input_specs(shape)
        bspecs = policies.batch_specs(cfg, shape, mesh, bsds)
        _check(bspecs, bsds, mesh, f"{arch} {sname}")


@pytest.mark.parametrize("arch", ["jamba-1.5-large-398b", "mixtral-8x22b"])
def test_fsdp_policy_engages_for_big_models(arch):
    mesh = _mesh(False)
    cfg = get_config(arch)
    pol = policies.resolve_policy(cfg, mesh)
    assert pol.fsdp_params


def test_small_models_stay_tp_only():
    mesh = _mesh(False)
    pol = policies.resolve_policy(get_config("minitron-8b"), mesh)
    assert not pol.fsdp_params


def test_decode_cache_seq_sharded():
    mesh = _mesh(False)
    cfg = get_config("qwen3-32b")
    model = build_model(cfg)
    shape = SHAPES["decode_32k"]
    bsds = model.input_specs(shape)
    bspecs = policies.batch_specs(cfg, shape, mesh, bsds)
    leaf = jax.tree.leaves(bspecs["caches"])[0]
    # (n_super, B, S, K, hd): batch over data, seq over model
    assert leaf.spec[1] is not None and leaf.spec[2] == "model"


def test_quantized_opt_specs_preserve_leading_sharding():
    mesh = _mesh(False)
    cfg = get_config("jamba-1.5-large-398b")
    model = build_model(cfg)
    pshapes, roles = abstract_init(model)
    pspecs = policies.param_specs(roles, pshapes, cfg, mesh)
    ospecs = policies.opt_state_specs(pspecs, pshapes, mesh, cfg,
                                      quantized=True)
    import jax.tree_util as jtu
    # every quantized leaf dict has the four keys with NamedShardings
    leaves = jtu.tree_leaves(ospecs, is_leaf=lambda x: isinstance(x, dict)
                             and ("mq" in x or "m" in x))
    assert any("mq" in l for l in leaves)
