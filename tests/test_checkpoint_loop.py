"""Checkpoint manager + fault-tolerant loop + data pipeline."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.train.checkpoint import CheckpointManager
from repro.train.data import DataConfig, SyntheticDataset, global_batch_np, \
    host_shard
from repro.train.elastic import (SimulatedFailures, StragglerWatchdog,
                                 factor_mesh, largest_viable_mesh)
from repro.train.loop import LoopConfig, train_loop
from repro.train.optimizer import adamw
from repro.train.train_step import make_train_step


def test_checkpoint_roundtrip(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones((4,), jnp.bfloat16), jnp.asarray(3, jnp.int32)]}
    ckpt.save(5, tree, extras={"note": "x"})
    template = jax.tree.map(jnp.zeros_like, tree)
    back, meta = ckpt.restore(template)
    assert meta["step"] == 5 and meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_retention_and_atomicity(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = {"w": jnp.ones((2,))}
    for s in (1, 2, 3, 4):
        ckpt.save(s, tree)
    assert ckpt.all_steps() == [3, 4]
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_data_determinism_and_sharding():
    cfg = DataConfig(vocab=97, seq=16, global_batch=8)
    a = global_batch_np(cfg, 3)
    b = global_batch_np(cfg, 3)
    np.testing.assert_array_equal(a, b)
    shards = [host_shard(cfg, 3, h, 4) for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(shards, 0), a)
    assert a.min() >= 0 and a.max() < 97


@pytest.mark.slow
def test_loop_survives_failure(tmp_path):
    cfg = get_config("mamba2-370m", smoke=True)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = adamw(1e-3)
    step = jax.jit(make_train_step(model, opt))
    data = SyntheticDataset(DataConfig(vocab=cfg.vocab, seq=32,
                                       global_batch=4))
    res = train_loop(step, params, opt.init(params), data,
                     LoopConfig(total_steps=14, checkpoint_every=5,
                                checkpoint_dir=str(tmp_path), log_every=100),
                     failures=SimulatedFailures(fail_at=(7,)),
                     log=lambda *_: None)
    assert res["restarts"] == 1
    assert res["step"] == 14
    assert np.isfinite(res["losses"]).all()


def test_elastic_mesh_factoring():
    assert factor_mesh(512, 16, prefer_pods=2) == (2, 16, 16)
    assert factor_mesh(256, 16) == (1, 16, 16)
    assert factor_mesh(255, 16) is None
    # lose 16 chips: largest viable mesh keeps TP=16, shrinks data
    shape = largest_viable_mesh(240, 16, batch_divisor=256)
    assert shape is not None
    pods, data, model = shape
    assert model == 16 and 256 % data == 0


def test_straggler_watchdog():
    wd = StragglerWatchdog(factor=2.0)
    for _ in range(10):
        assert not wd.observe(0.1)
    assert wd.observe(0.5)
    assert wd.flagged == 1
