"""Fleet-scale execution: 2-D (lanes x seeds) mesh equivalence, seed-
invariant work sharing, shard packing, and the jax.distributed scaffolding.

The load-bearing invariant: per-(lane, seed) work never crosses a device
and the only collectives are scalar any-lane cond gates, so EVERY mesh
shape — 1 device, 4x1, 2x2, 1x4, auto-factored — and both settings of
REPRO_SEED_SHARE produce bit-identical SweepResult metrics and variance
bands, including when the seed axis needs padding (S=3 on a 2- or 4-wide
seed dim repeats slot 0, whose outputs are never read back).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.nmp import NMPConfig, make_trace, partition
from repro.nmp import plan as plan_mod
from repro.nmp.scenarios import Scenario, seed_variants

CFG = NMPConfig()


# ---------------------------------------------------------------------------
# Shard packing (plan layer, in-process)
# ---------------------------------------------------------------------------

def _mixed_plan():
    grid = []
    tr = make_trace("KM", n_ops=256)
    grid += seed_variants(Scenario(name="KM/aimm", trace=tr, mapper="aimm",
                                   episodes=2), seeds=(0, 1, 2))
    tr2 = make_trace("RBM", n_ops=256)
    grid += [Scenario(name="RBM/none", trace=tr2, mapper="none")]
    return plan_mod.plan_grid(grid, CFG)


def test_packed_order_and_padding_waste():
    plan = _mixed_plan()
    # declaration order (test-pinned elsewhere) is untouched; only the
    # execution order is packed, heaviest padded cost first
    order = plan_mod.packed_group_order(plan, lane_dim=2, seed_dim=2)
    assert sorted(order) == list(range(len(plan.groups)))
    costs = [plan_mod.group_padded_cells(plan.groups[i], 2, 2)
             for i in order]
    assert costs == sorted(costs, reverse=True)
    # waste is a ratio in [0, 1): zero without a mesh, positive when a
    # 4-wide lane dim pads the 1-lane groups
    assert plan_mod.padding_waste(plan) == 0.0
    assert 0.0 < plan_mod.padding_waste(plan, lane_dim=4, seed_dim=1) < 1.0
    # lanes inside each group are cost-ordered (heaviest first)
    for g in plan.groups:
        c = [plan_mod.lane_cost(ln) for ln in g.lanes]
        assert c == sorted(c, reverse=True)


def test_seed_share_env_validation(monkeypatch):
    for raw, want in (("", True), ("on", True), ("1", True),
                      ("off", False), ("0", False)):
        monkeypatch.setenv("REPRO_SEED_SHARE", raw)
        assert plan_mod.seed_share_enabled() is want
    monkeypatch.setenv("REPRO_SEED_SHARE", "maybe")
    with pytest.raises(ValueError, match="REPRO_SEED_SHARE"):
        plan_mod.seed_share_enabled()


# ---------------------------------------------------------------------------
# Seed-invariant work sharing (in-process, single device)
# ---------------------------------------------------------------------------

def test_seed_share_on_off_bit_identical(monkeypatch):
    """Hoisting the trace-derived per-epoch work out of the seed vmap must
    not change a single bit of any seed's metrics."""
    from repro.nmp.sweep import run_grid
    tr = make_trace("KM", n_ops=192)
    grid = seed_variants(Scenario(name="KM/aimm", trace=tr, mapper="aimm",
                                  episodes=2), seeds=(0, 1))
    monkeypatch.setenv("REPRO_SEED_SHARE", "off")
    r_off = run_grid(grid, CFG)
    monkeypatch.setenv("REPRO_SEED_SHARE", "on")
    r_on = run_grid(grid, CFG)
    assert not r_off.plan.groups[0].flags.share_seed_inv
    assert r_on.plan.groups[0].flags.share_seed_inv
    for k in sorted(r_off.metrics):
        np.testing.assert_array_equal(r_off.metrics[k], r_on.metrics[k],
                                      err_msg=k)
    assert r_off.variance_band(0) == r_on.variance_band(0)


# ---------------------------------------------------------------------------
# 2-D mesh equivalence (forced 4-device host platform, subprocess)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = textwrap.dedent("""
    import os
    import numpy as np
    import jax
    assert jax.device_count() == 4, jax.devices()

    from repro.nmp import NMPConfig, make_trace
    from repro.nmp.scenarios import Scenario, seed_variants
    from repro.nmp.sweep import run_grid

    cfg = NMPConfig()
    grid = []
    for app in ("KM", "PR"):
        tr = make_trace(app, n_ops=256)
        # S=3 does NOT divide the 2- or 4-wide seed dims -> seed padding
        grid += seed_variants(
            Scenario(name=f"{app}/aimm", trace=tr, mapper="aimm",
                     episodes=2), seeds=(0, 1, 2))
        grid += [Scenario(name=f"{app}/none", trace=tr, mapper="none")]

    def run(env):
        for k in ("REPRO_SWEEP_DEVICES", "REPRO_SWEEP_MESH"):
            os.environ.pop(k, None)
        os.environ.update(env)
        return run_grid(grid, cfg)

    ref = run({"REPRO_SWEEP_DEVICES": "1"})
    assert (ref.n_devices, ref.mesh_shape) == (1, (1, 1))
    runs = {"4x1": run({"REPRO_SWEEP_MESH": "4x1"}),
            "2x2": run({"REPRO_SWEEP_MESH": "2x2"}),
            "1x4": run({"REPRO_SWEEP_MESH": "1x4"}),
            "auto": run({})}
    for name, r in runs.items():
        assert r.n_devices == 4, (name, r.n_devices)
        if name != "auto":
            assert r.mesh_shape == tuple(
                int(x) for x in name.split("x")), (name, r.mesh_shape)
        for k in sorted(ref.metrics):
            np.testing.assert_array_equal(ref.metrics[k], r.metrics[k],
                                          err_msg=f"{name}:{k}")
        for lane in range(len(grid)):
            assert ref.variance_band(lane) == r.variance_band(lane), (
                name, lane)
    print("MESH-OK", runs["auto"].mesh_shape)
""")


@pytest.mark.slow
def test_mesh_shapes_bit_identical_on_forced_host_devices():
    env = dict(
        os.environ,
        XLA_FLAGS=("--xla_force_host_platform_device_count=4 "
                   + os.environ.get("XLA_FLAGS", "")),
        JAX_PLATFORMS="cpu",
    )
    for k in ("REPRO_SWEEP_DEVICES", "REPRO_SWEEP_MESH", "REPRO_SEED_SHARE"):
        env.pop(k, None)
    proc = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MESH-OK" in proc.stdout


# ---------------------------------------------------------------------------
# jax.distributed scaffolding (2 local processes, subprocess)
# ---------------------------------------------------------------------------

def test_distributed_disabled_is_single_host(monkeypatch):
    monkeypatch.delenv("REPRO_DIST_COORD", raising=False)
    assert partition.maybe_init_distributed() is False
    # coord set without the group size/rank is a config error, named
    monkeypatch.setenv("REPRO_DIST_COORD", "127.0.0.1:9999")
    monkeypatch.delenv("REPRO_DIST_NPROCS", raising=False)
    monkeypatch.delenv("REPRO_DIST_RANK", raising=False)
    with pytest.raises(ValueError, match="REPRO_DIST_NPROCS"):
        partition.maybe_init_distributed()
    monkeypatch.setenv("REPRO_DIST_NPROCS", "two")
    monkeypatch.setenv("REPRO_DIST_RANK", "0")
    with pytest.raises(ValueError, match="must be integers"):
        partition.maybe_init_distributed()


_DIST_SCRIPT = textwrap.dedent("""
    import sys
    from repro.nmp import partition
    assert partition.maybe_init_distributed() is True
    assert partition.maybe_init_distributed() is True   # idempotent
    import jax
    assert jax.process_count() == 2, jax.process_count()
    # each process contributes its 2 forced host devices to the global mesh
    assert jax.device_count() == 4, jax.device_count()
    assert jax.local_device_count() == 2
    devs = partition.sweep_devices()
    assert len(devs) == 4
    print(f"rank{jax.process_index()} DIST-OK", flush=True)
""")


@pytest.mark.slow
def test_distributed_init_two_local_processes(tmp_path):
    """Two local processes join one jax.distributed group and see a 4-device
    global platform (2 forced host devices each).  The CPU backend cannot
    *execute* cross-process computations (jax 0.4.37 raises
    "Multiprocess computations aren't implemented on the CPU backend"), so
    this exercises exactly what the scaffolding claims: process-group init,
    global device visibility, and graceful single-host degradation when the
    knobs are unset."""
    base = dict(
        os.environ,
        XLA_FLAGS=("--xla_force_host_platform_device_count=2 "
                   + os.environ.get("XLA_FLAGS", "")),
        JAX_PLATFORMS="cpu",
        REPRO_DIST_COORD="127.0.0.1:19731",
        REPRO_DIST_NPROCS="2",
    )
    for k in ("REPRO_SWEEP_DEVICES", "REPRO_SWEEP_MESH", "REPRO_DIST_RANK"):
        base.pop(k, None)
    procs = [subprocess.Popen([sys.executable, "-c", _DIST_SCRIPT],
                              env=dict(base, REPRO_DIST_RANK=str(r)),
                              stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE, text=True)
             for r in range(2)]
    outs = [p.communicate(timeout=300) for p in procs]
    for r, (p, (out, err)) in enumerate(zip(procs, outs)):
        assert p.returncode == 0, f"rank{r}: {err[-3000:]}"
        assert f"rank{r} DIST-OK" in out
