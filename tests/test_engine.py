"""Integration tests: the NMP epoch engine and its baselines/mappers.

Traces come from the shared session-scoped fixtures in conftest.py (small
sizes, one construction per session) so the suite stays fast.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nmp import NMPConfig, make_trace, run_episode, run_program
from repro.nmp.paging import default_alloc, hoard_alloc
from repro.nmp.stats import opc_timeline, summarize

CFG = NMPConfig()


@pytest.mark.parametrize("technique", ["bnmp", "ldb", "pei"])
def test_baseline_techniques_run(spmv_trace, technique):
    res = run_episode(spmv_trace, CFG, technique=technique, mapper="none")
    s = summarize(res)
    assert s["ops"] == spmv_trace.n_ops   # every op processed exactly once
    assert s["cycles"] > 0
    assert 0 < s["opc"] < 10
    assert 0 <= s["compute_util"] <= 1
    assert s["migrations"] == 0


def test_tom_mapper_commits(spmv_trace):
    res = run_episode(spmv_trace, CFG, technique="bnmp", mapper="tom")
    assert int(res.env.tom_active) >= 0    # a candidate was committed
    assert summarize(res)["ops"] == spmv_trace.n_ops


def test_aimm_scripted_source_compute(spmv_trace):
    res = run_episode(spmv_trace, CFG, technique="bnmp", mapper="aimm",
                      forced_action=5)
    # source-compute remaps fill the remap table with the sentinel C
    assert int((res.env.compute_remap == CFG.n_cubes).sum()) > 0
    assert summarize(res)["ops"] == spmv_trace.n_ops


def test_aimm_scripted_migration(spmv_trace):
    res = run_episode(spmv_trace, CFG, technique="bnmp", mapper="aimm",
                      forced_action=1)
    s = summarize(res)
    assert s["migrations"] > 0
    assert 0 < s["frac_pages_migrated"] <= 1
    # page table remains valid
    p2c = np.asarray(res.env.page_to_cube)
    assert (p2c >= 0).all() and (p2c < CFG.n_cubes).all()


@pytest.mark.slow
def test_aimm_learned_run_and_continual_agent(spmv_trace):
    results = run_program(spmv_trace, CFG, technique="bnmp", mapper="aimm",
                          episodes=2, seed=0)
    a0, a1 = results[0].agent, results[1].agent
    assert int(a1.step) > int(a0.step)        # DNN persisted across episodes
    assert int(a1.replay.size) > 0
    for r in results:
        assert summarize(r)["ops"] == spmv_trace.n_ops


def test_hoard_alloc_colocates_programs():
    from repro.nmp.traces import merge_traces, program_of_page
    m = merge_traces([make_trace("KM", n_ops=256), make_trace("RD", n_ops=256)])
    table = hoard_alloc(m.n_pages, CFG, program_of_page(m))
    owner = program_of_page(m)
    cubes0 = set(table[owner == 0].tolist())
    cubes1 = set(table[owner == 1].tolist())
    assert cubes0.isdisjoint(cubes1)          # disjoint cube regions


def test_hoard_alloc_skips_zero_page_programs():
    """A program id with zero pages (id gap / departed co-runner) must not
    claim a cube share: every cube goes to the populated programs, and their
    spans still cover all pages with legal cube ids."""
    owner = np.asarray([0] * 12 + [2] * 4, np.int32)   # program 1 is empty
    table = hoard_alloc(16, CFG, owner)
    assert (table >= 0).all() and (table < CFG.n_cubes).all()
    cubes0 = set(table[owner == 0].tolist())
    cubes2 = set(table[owner == 2].tolist())
    assert cubes0.isdisjoint(cubes2)
    # the empty program starves nobody: all 16 cubes are split between the
    # two populated programs, proportionally (12:4 pages -> 12:4 cubes)
    assert len(cubes0) == 12 and len(cubes2) == 4
    # a fully-degenerate tail of empty programs changes nothing
    owner2 = np.asarray([0] * 12 + [5] * 4, np.int32)  # ids 1..4 all empty
    table2 = hoard_alloc(16, CFG, owner2)
    assert len(set(table2[owner2 == 0].tolist())) == 12
    # more populated programs than cubes: overlap is unavoidable, but spans
    # wrap round-robin instead of collapsing onto cube 0
    owner3 = np.arange(20, dtype=np.int32)             # 20 single-page programs
    table3 = hoard_alloc(20, CFG, owner3)
    assert (table3 >= 0).all() and (table3 < CFG.n_cubes).all()
    occupancy = np.bincount(table3, minlength=CFG.n_cubes)
    assert occupancy.max() <= 2                        # balanced, not piled


def test_hoard_alloc_zero_page_trace_and_owner_mismatch():
    """A zero-page trace used to crash hoard_alloc (empty bincount/argmax);
    it must degrade to an empty allocation.  A program-owner vector whose
    length disagrees with n_pages is a caller bug and must be a clear
    ValueError, not a silent mis-allocation."""
    table = hoard_alloc(0, CFG, np.zeros(0, np.int32))
    assert table.shape == (0,) and table.dtype == np.int32
    with pytest.raises(ValueError, match="one owner per page"):
        hoard_alloc(16, CFG, np.zeros(8, np.int32))


def test_page_cache_depths_follow_config():
    """PageInfoCache history depths come from NMPConfig (satellite): custom
    depths resize the cache rows AND the matching state-vector slices, and
    the defaults reproduce the historical 8/8/4/4 layout."""
    from repro.nmp.engine import state_spec_for
    from repro.nmp.paging import init_page_cache
    cache = init_page_cache(CFG)
    assert cache.hop_hist.shape[1] == 8 and cache.lat_hist.shape[1] == 8
    assert cache.mig_hist.shape[1] == 4 and cache.act_hist.shape[1] == 4
    spec = state_spec_for(CFG)
    assert (spec.hop_hist, spec.lat_hist, spec.mig_hist, spec.act_hist) == \
        (8, 8, 4, 4)

    cfg2 = NMPConfig(hop_hist=4, lat_hist=2, mig_hist=3, act_hist=6)
    cache2 = init_page_cache(cfg2)
    assert cache2.hop_hist.shape[1] == 4 and cache2.lat_hist.shape[1] == 2
    assert cache2.mig_hist.shape[1] == 3 and cache2.act_hist.shape[1] == 6
    spec2 = state_spec_for(cfg2)
    assert spec2.dim == spec.dim - (8 + 8 + 4 + 4) + (4 + 2 + 3 + 6)
    # and the engine runs end-to-end with the resized state vector
    res = run_episode(make_trace("KM", n_ops=256), cfg2, "bnmp", "aimm",
                      seed=0)
    assert summarize(res)["ops"] == 256


def test_8x8_mesh_runs():
    cfg = NMPConfig(mesh_x=8, mesh_y=8)
    res = run_episode(make_trace("RBM", n_ops=1024), cfg, "bnmp", "none")
    assert summarize(res)["ops"] == 1024


def test_opc_timeline_fixed_size(spmv_trace):
    res = run_episode(spmv_trace, CFG, "bnmp", "none")
    t = opc_timeline(res, samples=32)
    assert t.shape == (32,)
    assert (t > 0).all()


def test_interval_actions_change_invocation_rate(spmv_trace):
    res = run_episode(spmv_trace, CFG, technique="bnmp", mapper="aimm",
                      forced_action=6)   # INC_INTERVAL every invocation
    inv = np.asarray(res.metrics["invoke"])
    # interval rises to max stride 4 -> invocations sparse at the end
    assert inv.sum() < inv.shape[0]
    assert int(res.env.interval_level) == 3
