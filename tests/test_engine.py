"""Integration tests: the NMP epoch engine and its baselines/mappers.

Traces come from the shared session-scoped fixtures in conftest.py (small
sizes, one construction per session) so the suite stays fast.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nmp import NMPConfig, make_trace, run_episode, run_program
from repro.nmp.paging import default_alloc, hoard_alloc
from repro.nmp.stats import opc_timeline, summarize

CFG = NMPConfig()


@pytest.mark.parametrize("technique", ["bnmp", "ldb", "pei"])
def test_baseline_techniques_run(spmv_trace, technique):
    res = run_episode(spmv_trace, CFG, technique=technique, mapper="none")
    s = summarize(res)
    assert s["ops"] == spmv_trace.n_ops   # every op processed exactly once
    assert s["cycles"] > 0
    assert 0 < s["opc"] < 10
    assert 0 <= s["compute_util"] <= 1
    assert s["migrations"] == 0


def test_tom_mapper_commits(spmv_trace):
    res = run_episode(spmv_trace, CFG, technique="bnmp", mapper="tom")
    assert int(res.env.tom_active) >= 0    # a candidate was committed
    assert summarize(res)["ops"] == spmv_trace.n_ops


def test_aimm_scripted_source_compute(spmv_trace):
    res = run_episode(spmv_trace, CFG, technique="bnmp", mapper="aimm",
                      forced_action=5)
    # source-compute remaps fill the remap table with the sentinel C
    assert int((res.env.compute_remap == CFG.n_cubes).sum()) > 0
    assert summarize(res)["ops"] == spmv_trace.n_ops


def test_aimm_scripted_migration(spmv_trace):
    res = run_episode(spmv_trace, CFG, technique="bnmp", mapper="aimm",
                      forced_action=1)
    s = summarize(res)
    assert s["migrations"] > 0
    assert 0 < s["frac_pages_migrated"] <= 1
    # page table remains valid
    p2c = np.asarray(res.env.page_to_cube)
    assert (p2c >= 0).all() and (p2c < CFG.n_cubes).all()


@pytest.mark.slow
def test_aimm_learned_run_and_continual_agent(spmv_trace):
    results = run_program(spmv_trace, CFG, technique="bnmp", mapper="aimm",
                          episodes=2, seed=0)
    a0, a1 = results[0].agent, results[1].agent
    assert int(a1.step) > int(a0.step)        # DNN persisted across episodes
    assert int(a1.replay.size) > 0
    for r in results:
        assert summarize(r)["ops"] == spmv_trace.n_ops


def test_hoard_alloc_colocates_programs():
    from repro.nmp.traces import merge_traces, program_of_page
    m = merge_traces([make_trace("KM", n_ops=256), make_trace("RD", n_ops=256)])
    table = hoard_alloc(m.n_pages, CFG, program_of_page(m))
    owner = program_of_page(m)
    cubes0 = set(table[owner == 0].tolist())
    cubes1 = set(table[owner == 1].tolist())
    assert cubes0.isdisjoint(cubes1)          # disjoint cube regions


def test_8x8_mesh_runs():
    cfg = NMPConfig(mesh_x=8, mesh_y=8)
    res = run_episode(make_trace("RBM", n_ops=1024), cfg, "bnmp", "none")
    assert summarize(res)["ops"] == 1024


def test_opc_timeline_fixed_size(spmv_trace):
    res = run_episode(spmv_trace, CFG, "bnmp", "none")
    t = opc_timeline(res, samples=32)
    assert t.shape == (32,)
    assert (t > 0).all()


def test_interval_actions_change_invocation_rate(spmv_trace):
    res = run_episode(spmv_trace, CFG, technique="bnmp", mapper="aimm",
                      forced_action=6)   # INC_INTERVAL every invocation
    inv = np.asarray(res.metrics["invoke"])
    # interval rises to max stride 4 -> invocations sparse at the end
    assert inv.sum() < inv.shape[0]
    assert int(res.env.interval_level) == 3
