"""The pluggable topology layer: routing-tensor invariants on every builder,
bit-compatibility of `mesh2d` with the historical XY model, link-load
conservation, migration no-ops, and the topology axis through the sweep
pipeline (grouping + mixed-topology bit-identity vs serial).

None of these are marked slow, so the whole file also runs on the forced
4-device CI job (`make test-4dev`) where every grid is sharded over a
4-wide lane mesh — the mixed-topology equivalence below is therefore
exercised sharded and unsharded.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.nmp import NMPConfig, make_trace
from repro.nmp.config import NMPConfig as _Cfg
from repro.nmp.migration import migration_cost
from repro.nmp.scenarios import Scenario, topology_grid
from repro.nmp.sweep import run_grid, run_grid_serial
from repro.nmp.topology import (TOPOLOGIES, build_topology, get_topology,
                                hop_count, link_loads)

CFG = NMPConfig()
ALL_CFGS = {name: NMPConfig(topology=name) for name in TOPOLOGIES}


# ---------------------------------------------------------------------------
# Structural invariants (every builder)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_routing_tensor_invariants(name):
    topo = get_topology(ALL_CFGS[name])
    C, L = topo.n_cubes, topo.n_links
    assert topo.hops.shape == (C, C) and topo.route_links.shape == (C, C, L)
    # hops symmetric, zero diagonal, connected
    assert (topo.hops == topo.hops.T).all()
    assert (np.diag(topo.hops) == 0).all()
    assert (topo.hops[~np.eye(C, dtype=bool)] > 0).all()
    # a route uses each link at most once and exactly `hops` links in total
    assert set(np.unique(topo.route_links)) <= {0.0, 1.0}
    np.testing.assert_array_equal(topo.route_links.sum(axis=-1), topo.hops)
    # self-routes are empty (what makes same-cube migration an exact no-op)
    assert topo.route_links[np.arange(C), np.arange(C)].sum() == 0
    # neighbor table: valid slots are exactly the hop-1 cubes
    for c in range(C):
        nbrs = set(topo.nbr[c][topo.nbr_valid[c]].tolist())
        assert nbrs == set(np.flatnonzero(topo.hops[c] == 1).tolist())
        # invalid slots are self-padded => always a legal cube id
        assert set(topo.nbr[c].tolist()) <= set(range(C)) and \
            (topo.nbr[c][~topo.nbr_valid[c]] == c).all()
    # far targets are legal and never the cube itself
    assert (topo.far != np.arange(C)).all()
    # nearest-MC: every MC cube maps to its own controller
    for i, cube in enumerate(topo.mc_cubes):
        assert topo.nearest_mc[cube] == i


def test_link_counts():
    assert get_topology(ALL_CFGS["mesh2d"]).n_links == 24      # 2*4*3
    assert get_topology(ALL_CFGS["torus2d"]).n_links == 32     # 2*16
    assert get_topology(ALL_CFGS["ring"]).n_links == 16
    # dragonfly: 4 groups x C(4,2) intra + C(4,2) global
    assert get_topology(ALL_CFGS["dragonfly"]).n_links == 30
    cfg8 = NMPConfig(mesh_x=8, mesh_y=8)
    assert get_topology(cfg8).n_links == 8 * 7 * 2
    assert int(get_topology(cfg8).hops[0, 63]) == 14


def test_unknown_topology_raises():
    with pytest.raises(ValueError, match="unknown topology"):
        build_topology(NMPConfig(topology="hypercube"))


def test_duplicate_mc_attachment_rejected():
    """Geometries too small to host n_mcs distinct controllers fail loudly at
    build time instead of silently under-injecting (a 2-group dragonfly
    attaches its 4 MCs at 4 distinct cubes; a 2-cube ring cannot)."""
    topo = build_topology(NMPConfig(topology="dragonfly", mesh_x=8, mesh_y=2))
    assert len(set(topo.mc_cubes)) == 4
    with pytest.raises(ValueError, match="duplicate MC attachment"):
        build_topology(NMPConfig(topology="ring", mesh_x=2, mesh_y=1))
    # mesh2d pins one MC per CMP corner: any other n_mcs must fail loudly
    # (the engine sizes its MC-queue state to n_mcs), while ring/dragonfly
    # honor n_mcs via evenly spaced attachment
    with pytest.raises(ValueError, match="MC attachment cubes for n_mcs=2"):
        build_topology(NMPConfig(topology="mesh2d", n_mcs=2))
    assert build_topology(NMPConfig(topology="ring", n_mcs=2)).mc_cubes == \
        (0, 8)


# ---------------------------------------------------------------------------
# mesh2d == historical XY model
# ---------------------------------------------------------------------------

def test_mesh2d_matches_manhattan_and_mirror():
    topo = get_topology(CFG)
    X, Y = CFG.mesh_x, CFG.mesh_y
    cx, cy = np.arange(16) % X, np.arange(16) // X
    np.testing.assert_array_equal(
        topo.hops, np.abs(cx[:, None] - cx[None, :])
        + np.abs(cy[:, None] - cy[None, :]))
    # far = mirror through the array center (the paper's diagonally opposite
    # cube), NOT the hop-farthest cube
    np.testing.assert_array_equal(topo.far, (Y - 1 - cy) * X + (X - 1 - cx))
    assert int(topo.far[5]) == 10                   # (1,1) -> (2,2)
    assert topo.mc_cubes == CFG.mc_cubes
    # corner-adjacent MCs: each corner cube maps to its own MC
    assert int(hop_count(topo, jnp.asarray(0), jnp.asarray(15))) == 6


def test_mesh2d_xy_route_shape():
    """XY routing: X at the source row then Y at the destination column —
    route (0 -> 15) uses row-0 horizontal links and column-3 verticals."""
    topo = get_topology(CFG)
    X, Y = CFG.mesh_x, CFG.mesh_y
    H = Y * (X - 1)
    route = np.flatnonzero(topo.route_links[0, 15])
    assert route.tolist() == [0, 1, 2,                       # row 0, x=0..2
                              H + 3 * (Y - 1) + 0,           # col 3, y=0..2
                              H + 3 * (Y - 1) + 1,
                              H + 3 * (Y - 1) + 2]


# ---------------------------------------------------------------------------
# Conservation (satellite): every topology, random flow batches
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=20)
@given(st.sampled_from(sorted(TOPOLOGIES)),
       st.lists(st.tuples(st.integers(0, 15), st.integers(0, 15),
                          st.integers(1, 9)),
                min_size=1, max_size=24))
def test_link_load_conservation_all_topologies(name, flows):
    """Total accumulated link load == sum(weight * hops) on every topology:
    minimal routes place exactly `hops` link traversals per flow."""
    topo = get_topology(ALL_CFGS[name])
    src = jnp.asarray([f[0] for f in flows])
    dst = jnp.asarray([f[1] for f in flows])
    w = jnp.asarray([float(f[2]) for f in flows])
    loads = link_loads(topo, src, dst, w)
    assert loads.shape[0] == topo.n_links
    assert (np.asarray(loads) >= 0).all()
    total = float(loads.sum())
    expect = float((w * hop_count(topo, src, dst)).sum())
    assert total == expect       # exact: integer weights over 0/1 incidence


@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_migration_same_cube_is_exact_noop(name):
    """`migration_cost` must be an exact no-op (zero latency, zero stall,
    zero link loads) when old_cube == new_cube, on every topology."""
    cfg = ALL_CFGS[name]
    for cube in (0, 7, 15):
        lat, stall, loads = migration_cost(
            jnp.asarray(cube), jnp.asarray(cube), jnp.asarray(True),
            jnp.asarray(12.0), cfg)
        assert float(lat) == 0.0 and float(stall) == 0.0
        assert float(jnp.abs(loads).sum()) == 0.0
        assert loads.shape == (get_topology(cfg).n_links,)


@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_migration_moving_page_charges_route(name):
    cfg = ALL_CFGS[name]
    topo = get_topology(cfg)
    lat, stall, loads = migration_cost(
        jnp.asarray(0), jnp.asarray(5), jnp.asarray(False),
        jnp.asarray(3.0), cfg)
    hops = float(topo.hops[0, 5])
    assert float(lat) == cfg.page_flits + hops * cfg.t_router + cfg.t_page_walk
    assert float(loads.sum()) == hops * cfg.page_flits
    assert float(stall) > 0.0


# ---------------------------------------------------------------------------
# Topology axis through the sweep pipeline
# ---------------------------------------------------------------------------

def test_plan_groups_by_topology():
    """Lanes of different interconnects compile separate programs; lanes of
    one interconnect keep the historical grouping."""
    from repro.nmp.plan import plan_grid
    tr = make_trace("KM", n_ops=384)
    grid = [Scenario(name="m/none", trace=tr),
            Scenario(name="r/none", trace=tr, topology="ring"),
            Scenario(name="m/aimm", trace=tr, mapper="aimm"),
            Scenario(name="r/aimm", trace=tr, mapper="aimm", topology="ring"),
            Scenario(name="m2/tom", trace=tr, mapper="tom",
                     topology="mesh2d")]
    plan = plan_grid(grid, CFG)
    assert [(g.topology, g.has_agent, g.n_lanes) for g in plan.groups] == [
        ("mesh2d", True, 1), ("ring", True, 1),
        ("mesh2d", False, 2), ("ring", False, 1)]
    assert plan.topologies == ("mesh2d", "ring", "mesh2d", "ring", "mesh2d")
    # topology is part of the fold key: same cell, different interconnect
    assert all(len(ln.indices) == 1 for g in plan.groups for ln in g.lanes)


def test_plan_rejects_unknown_topology():
    from repro.nmp.plan import plan_grid
    tr = make_trace("KM", n_ops=384)
    with pytest.raises(ValueError, match="unknown topology"):
        plan_grid([Scenario(name="x", trace=tr, topology="moebius")], CFG)


def test_plan_rejects_lineage_spanning_topologies():
    """One lineage tag across interconnects would compile per-topology
    programs whose final agents overwrite each other in the PolicyStore —
    rejected at plan time (distinct tags per topology are fine)."""
    from repro.nmp.plan import plan_grid
    tr = make_trace("KM", n_ops=384)
    with pytest.raises(ValueError, match="spans topologies"):
        plan_grid([Scenario(name="m", trace=tr, mapper="aimm", lineage="t"),
                   Scenario(name="r", trace=tr, mapper="aimm", lineage="t",
                            topology="ring")], CFG)
    plan = plan_grid([Scenario(name="m", trace=tr, mapper="aimm",
                               lineage="t-mesh"),
                      Scenario(name="r", trace=tr, mapper="aimm",
                               lineage="t-ring", topology="ring")], CFG)
    assert [(g.topology, g.lineage) for g in plan.groups] == [
        ("mesh2d", True), ("ring", True)]


def test_mixed_topology_grid_matches_serial():
    """A grid spanning all four interconnects — unmanaged + scripted-AIMM
    lanes per topology plus a learned-AIMM torus lane — reproduces per-lane
    serial `run_episode`/`run_program` bit-for-bit (runs sharded on the
    forced-4-device CI job, unsharded otherwise)."""
    tr = make_trace("KM", n_ops=384)
    grid = []
    for topo in sorted(TOPOLOGIES):
        grid.append(Scenario(name=f"{topo}/none", trace=tr, topology=topo))
        grid.append(Scenario(name=f"{topo}/forced", trace=tr, mapper="aimm",
                             forced_action=1, topology=topo, seed=3))
    grid.append(Scenario(name="torus2d/learned", trace=tr, mapper="aimm",
                         topology="torus2d", episodes=2))
    res = run_grid(grid, CFG)
    serial = run_grid_serial(grid, CFG)
    for i, sc in enumerate(grid):
        batched = res.episode_summary(i)
        for k in ("cycles", "ops", "opc"):
            assert serial[i][k] == batched[k], (sc.name, k)
    # final env stacks across link spaces: padded to the widest topology
    n_links_max = max(get_topology(c) .n_links for c in ALL_CFGS.values())
    assert res.final_env.pending_mig_loads.shape == (len(grid), n_links_max)


def test_topology_grid_builder():
    grid = topology_grid(apps=("KM",), n_ops=384)
    assert len(grid) == 2 * len(TOPOLOGIES)
    assert {sc.topology for sc in grid} == set(TOPOLOGIES)
    with pytest.raises(ValueError, match="unknown topology"):
        topology_grid(topologies=("kleinbottle",))


def test_mesh2d_default_config_unchanged():
    """The default config still names the paper's mesh — the whole golden
    suite depends on it."""
    assert _Cfg().topology == "mesh2d"
    assert dataclasses.replace(CFG, topology="ring") != CFG
