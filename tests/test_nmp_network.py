"""Property tests for the cube network: hops, link loads, routing."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.nmp.config import NMPConfig
from repro.nmp.network import hop_count, link_loads, n_links, nearest_mc

CFG = NMPConfig()


def test_hop_count_basics():
    assert int(hop_count(jnp.asarray(0), jnp.asarray(0), 4)) == 0
    assert int(hop_count(jnp.asarray(0), jnp.asarray(15), 4)) == 6  # corners
    assert int(hop_count(jnp.asarray(0), jnp.asarray(3), 4)) == 3


@settings(deadline=None, max_examples=30)
@given(st.lists(st.tuples(st.integers(0, 15), st.integers(0, 15)),
                min_size=1, max_size=20))
def test_link_load_conservation(flows):
    """Sum of per-link loads == sum over flows of weight * hops (XY routes
    place exactly `hops` link traversals per flow)."""
    src = jnp.asarray([f[0] for f in flows])
    dst = jnp.asarray([f[1] for f in flows])
    w = jnp.ones(len(flows)) * 3.0
    loads = link_loads(src, dst, w, CFG)
    total = float(loads.sum())
    expect = float((w * hop_count(src, dst, CFG.mesh_x)).sum())
    np.testing.assert_allclose(total, expect, rtol=1e-5)
    assert loads.shape[0] == n_links(CFG)
    assert (np.asarray(loads) >= 0).all()


def test_nearest_mc_corners():
    mc = np.asarray(nearest_mc(CFG))
    # each corner cube maps to its own MC
    for i, cube in enumerate(CFG.mc_cubes):
        assert mc[cube] == i


def test_8x8_mesh_links():
    cfg = NMPConfig(mesh_x=8, mesh_y=8)
    assert n_links(cfg) == 8 * 7 * 2
    assert int(hop_count(jnp.asarray(0), jnp.asarray(63), 8)) == 14
