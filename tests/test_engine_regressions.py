"""Engine regression guards: seeded determinism, conservation invariants,
and a scripted-policy smoke test per AIMM action."""
import numpy as np
import pytest

from repro.core.actions import N_ACTIONS
from repro.nmp import NMPConfig, run_episode
from repro.nmp.stats import summarize

CFG = NMPConfig()


def test_seeded_determinism_aimm(spmv_trace):
    """Same seed => identical EpisodeResult metrics (learned policy included)."""
    a = run_episode(spmv_trace, CFG, "bnmp", "aimm", seed=3)
    b = run_episode(spmv_trace, CFG, "bnmp", "aimm", seed=3)
    assert float(a.env.cycles) == float(b.env.cycles)
    np.testing.assert_array_equal(np.asarray(a.metrics["action"]),
                                  np.asarray(b.metrics["action"]))
    np.testing.assert_array_equal(np.asarray(a.metrics["opc"]),
                                  np.asarray(b.metrics["opc"]))


def test_different_seeds_may_diverge_but_conserve(spmv_trace):
    s1 = summarize(run_episode(spmv_trace, CFG, "bnmp", "aimm", seed=0))
    s2 = summarize(run_episode(spmv_trace, CFG, "bnmp", "aimm", seed=7))
    assert s1["ops"] == s2["ops"] == spmv_trace.n_ops


@pytest.mark.parametrize("mapper", ["none", "tom", "aimm"])
def test_op_conservation_all_mappers(km_trace, mapper):
    """Every trace op is processed exactly once regardless of mapper, and
    accesses to migrated pages never exceed total accesses."""
    s = summarize(run_episode(km_trace, CFG, "bnmp", mapper, seed=1))
    assert s["ops"] == km_trace.n_ops
    assert s["frac_access_migrated"] <= 1.0
    assert 0.0 <= s["frac_pages_migrated"] <= 1.0


@pytest.mark.parametrize("action", list(range(N_ACTIONS)))
def test_forced_action_smoke(km_trace, action):
    """Each scripted action runs, conserves ops, and keeps the page table and
    compute-remap table inside their legal ranges.

    forced_action is a traced value, so all eight cases share one compile."""
    res = run_episode(km_trace, CFG, "bnmp", "aimm", forced_action=action,
                      seed=action)
    s = summarize(res)
    assert s["ops"] == km_trace.n_ops
    p2c = np.asarray(res.env.page_to_cube)
    assert (p2c >= 0).all() and (p2c < CFG.n_cubes).all()
    cr = np.asarray(res.env.compute_remap)
    assert ((cr >= -1) & (cr <= CFG.n_cubes)).all()
    assert float(res.env.access_on_migrated) <= float(res.env.access_total)
