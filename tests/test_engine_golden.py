"""Golden-value regression for the engine hot path, plus the cond-vs-masked
agent-gate equality check.

The GOLDEN table pins per-lane `cycles` / `ops` / `opc` of a small fixed-seed
grid as produced by the pre-optimization engine (PR 1: full O(P log P) EMA
sort, sort-based row-buffer distinct count, compute-then-mask agent path).
The optimized cost model (top_k PEI threshold, O(W) scatter-stamp distinct
count, statically skipped feature paths) must reproduce them bit-for-bit:
deterministic lanes and scripted-AIMM lanes exercise every technique and both
baseline mappers, including a trace long enough for TOM to profile + commit.

Learned-policy lanes are deliberately absent: the invocation-gated agent
(train/act under `lax.cond` per invocation instead of per epoch) is a
documented semantic change of PR 2, so their trajectories moved.  Their
correctness bar is the cond-vs-masked equality below plus the batched/serial
equivalence suite.
"""
import jax
import numpy as np
import pytest

from repro.nmp import NMPConfig, make_trace
from repro.nmp.engine import run_episode
from repro.nmp.stats import summarize

CFG = NMPConfig()

# (app, n_ops, technique, mapper, forced_action) -> (cycles, ops, opc),
# produced with seed=2 by the PR 1 engine (see module docstring).
GOLDEN = {
    ("KM", 384, "bnmp", "none", -1): (427.58953857421875, 384.0, 0.898057518620389),
    ("KM", 384, "bnmp", "tom", -1): (427.58953857421875, 384.0, 0.898057518620389),
    ("KM", 384, "ldb", "none", -1): (651.998779296875, 384.0, 0.5889581578881347),
    ("KM", 384, "ldb", "tom", -1): (651.998779296875, 384.0, 0.5889581578881347),
    ("KM", 384, "pei", "none", -1): (568.667236328125, 384.0, 0.6752630984677115),
    ("KM", 384, "pei", "tom", -1): (568.667236328125, 384.0, 0.6752630984677115),
    ("KM", 384, "bnmp", "aimm", 1): (1374.1378173828125, 384.0, 0.2794479528489855),
    ("KM", 384, "pei", "aimm", 5): (580.667236328125, 384.0, 0.6613081916387104),
    ("SPMV", 2048, "bnmp", "none", -1): (5710.2119140625, 2048.0, 0.3586556910359849),
    ("SPMV", 2048, "bnmp", "tom", -1): (5710.2119140625, 2048.0, 0.3586556910359849),
    ("SPMV", 2048, "ldb", "none", -1): (5890.01708984375, 2048.0, 0.3477069707541934),
    ("SPMV", 2048, "ldb", "tom", -1): (5890.01708984375, 2048.0, 0.3477069707541934),
    ("SPMV", 2048, "pei", "none", -1): (5835.72412109375, 2048.0, 0.35094188099079593),
    ("SPMV", 2048, "pei", "tom", -1): (5835.72412109375, 2048.0, 0.35094188099079593),
    ("SPMV", 2048, "bnmp", "aimm", 1): (10183.484375, 2048.0, 0.20110994671212426),
    ("SPMV", 2048, "pei", "aimm", 5): (5927.9072265625, 2048.0, 0.3454844891672846),
}


@pytest.mark.parametrize("key", sorted(GOLDEN), ids=lambda k: "/".join(map(str, k)))
def test_hot_path_rewrite_preserves_golden_values(key):
    app, n_ops, tech, mapper, forced = key
    tr = make_trace(app, n_ops=n_ops)
    s = summarize(run_episode(tr, CFG, tech, mapper, seed=2,
                              forced_action=forced))
    want = GOLDEN[key]
    assert (s["cycles"], s["ops"], s["opc"]) == want, (key, s)


@pytest.mark.slow
def test_cond_agent_gate_equals_masked_reference():
    """The invocation-gated agent (`lax.cond` on any-lane-invokes + nested
    cond on replay readiness) must be bit-identical to the compute-every-epoch
    -and-mask reference path: same cycles, same action stream, same learned
    parameters."""
    tr = make_trace("SPMV", n_ops=1024)
    cond = run_episode(tr, CFG, "bnmp", "aimm", seed=3)
    masked = run_episode(tr, CFG, "bnmp", "aimm", seed=3, agent_gate="masked")
    assert float(cond.env.cycles) == float(masked.env.cycles)
    np.testing.assert_array_equal(np.asarray(cond.metrics["action"]),
                                  np.asarray(masked.metrics["action"]))
    np.testing.assert_array_equal(np.asarray(cond.metrics["opc"]),
                                  np.asarray(masked.metrics["opc"]))
    for c, m in zip(jax.tree.leaves(cond.agent.params),
                    jax.tree.leaves(masked.agent.params)):
        np.testing.assert_array_equal(np.asarray(c), np.asarray(m))
    for c, m in zip(jax.tree.leaves(cond.agent.replay),
                    jax.tree.leaves(masked.agent.replay)):
        np.testing.assert_array_equal(np.asarray(c), np.asarray(m))


def test_tom_gate_cond_equals_masked_reference():
    """TOM's profiling-phase candidate scoring runs under `lax.cond` on "any
    lane is in a profiling phase" (gated like the DQN invocation); it must be
    bit-identical to the score-every-epoch reference path: same cycles, same
    committed mapping, same candidate scores."""
    tr = make_trace("KM", n_ops=2048)      # long enough to profile + commit
    cond = run_episode(tr, CFG, "bnmp", "tom", seed=1)
    masked = run_episode(tr, CFG, "bnmp", "tom", seed=1, tom_gate="masked")
    assert float(cond.env.cycles) == float(masked.env.cycles)
    assert int(cond.env.tom_active) == int(masked.env.tom_active) >= 0
    np.testing.assert_array_equal(np.asarray(cond.env.tom_scores),
                                  np.asarray(masked.env.tom_scores))
    np.testing.assert_array_equal(np.asarray(cond.metrics["opc"]),
                                  np.asarray(masked.metrics["opc"]))


def test_agent_invocations_skip_between_strides():
    """With a scripted INC_INTERVAL policy the invocation stride climbs to 4;
    the invoke metric must go sparse accordingly (the whole point of gating
    the agent on `invoke`)."""
    tr = make_trace("SPMV", n_ops=2048)
    res = run_episode(tr, CFG, "bnmp", "aimm", forced_action=6, seed=0)
    inv = np.asarray(res.metrics["invoke"])
    assert int(res.env.interval_level) == 3
    # steady state: one invocation every 4 epochs
    assert inv[-8:].sum() == 2.0
