"""Fault-injection suite (nmp.faults + the serving/checkpoint recovery paths).

Pins the robustness contract: under each injected fault class only the
affected tenant degrades (retry -> quarantine) or rolls back, every other
tenant's results stay bit-identical to a fault-free run; crash-safe
checkpoints restore from the newest intact step (kill-resume subprocess
test); and corruption is detected at the per-leaf checksum level.
"""
import os
import signal
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro.core import agent as agent_mod
from repro.nmp import NMPConfig, faults, partition
from repro.nmp.continual import PolicyStore, run_stream
from repro.nmp.engine import default_agent_cfg
from repro.nmp.faults import FaultEvent, FaultPlan, InjectedFault
from repro.nmp.scenarios import tenant_fleet, tenant_stream
from repro.nmp.serving import MappingServer, solo_stream
from repro.nmp.traces import make_trace
from repro.train.checkpoint import CheckpointCorruptError, CheckpointManager

CFG = NMPConfig()
N_OPS = 384
SLOTS2 = partition.padded_lane_count(2, partition.build_mesh())


def _fleet(n_tenants, n_phases=2, apps=("KM", "SC")):
    return tenant_fleet(n_tenants=n_tenants, apps=apps, n_phases=n_phases,
                        n_ops_per_app=N_OPS)


def _assert_matches_solo(srv, tid, stream):
    solo = run_stream(solo_stream(tid, stream), CFG)
    for pi in range(len(stream)):
        served = srv.tenant_metrics(tid, pi)
        want = solo.phases[pi].metrics
        for k in sorted(want):
            np.testing.assert_array_equal(served[k], want[k][0],
                                          err_msg=f"{tid} phase{pi} {k}")


# -- the harness itself ---------------------------------------------------

def test_fault_plan_events_are_one_shot_and_deterministic():
    plan = FaultPlan([FaultEvent("fail_tick", at=1, tenant="x")], seed=7)
    assert plan.on_dispatch(0, ("x",)) == ()          # wrong ordinal: no fire
    with pytest.raises(InjectedFault) as ei:
        plan.on_dispatch(1, ("x", "y"))
    assert ei.value.tenant == "x"
    plan.on_dispatch(1, ("x",))                       # one-shot: spent
    assert plan.injected == [("fail_tick", 1, "x")]
    # events targeting an absent tenant do not fire (and stay unfired)
    plan2 = FaultPlan([FaultEvent("fail_tick", at=0, tenant="gone")])
    plan2.on_dispatch(0, ("other",))
    assert not plan2.events[0].fired
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent("explode")


def test_corrupt_bytes_is_seeded_deterministic(tmp_path):
    p1, p2 = tmp_path / "a.bin", tmp_path / "b.bin"
    payload = bytes(range(256)) * 8
    for p in (p1, p2):
        p.write_bytes(payload)
        faults.corrupt_bytes(str(p), np.random.default_rng(3), n_bytes=16)
    assert p1.read_bytes() == p2.read_bytes() != payload


# -- submit-boundary validation (satellite: input validation) -------------

def test_submit_rejects_poisoned_traces():
    tr = make_trace("KM", n_ops=N_OPS)
    stream = tenant_stream(apps=("KM",), n_phases=2, n_ops_per_app=N_OPS)
    srv = MappingServer(CFG, n_slots=2)
    import dataclasses
    bad_neg = [dataclasses.replace(sc, trace=faults.poison_trace(tr,
                                                                 "negative"))
               for (sc,) in stream]
    with pytest.raises(ValueError, match=r"tenant 'evil' phase 0.*negative"):
        srv.submit("evil", [[sc] for sc in bad_neg])
    bad_nan = dataclasses.replace(stream[1][0],
                                  trace=faults.poison_trace(tr, "nan"))
    with pytest.raises(ValueError, match=r"tenant 'evil' phase 1.*NaN"):
        srv.submit("evil", [stream[0], [bad_nan]])
    out_of_range = dataclasses.replace(
        tr, dest=np.full_like(np.asarray(tr.dest), tr.n_pages + 5))
    with pytest.raises(ValueError, match="outside the .*-page space"):
        srv.submit("evil", [[dataclasses.replace(stream[0][0],
                                                 trace=out_of_range)]])
    assert srv.stats()["faults"]["validation_rejects"] == 3
    # a rejected submit leaves no tenant behind; the id stays usable
    srv.submit("evil", stream)
    srv.run()
    assert srv.tenant("evil").done


# -- divergence guard + retry + isolation ---------------------------------

def test_poisoned_warm_agent_retries_bit_identical():
    """A transiently poisoned warm agent (NaN params at dispatch) must be
    caught by the per-tick finite guard BEFORE the store is written, and the
    retry — fault events are one-shot — must reproduce the fault-free
    results bit-identically for EVERY tenant, poisoned one included."""
    fleet = _fleet(3, n_phases=2)
    plan = FaultPlan([FaultEvent("poison_agent", at=1, tenant="t001")])
    srv = MappingServer(CFG, n_slots=2, faults=plan, backoff_base_s=0.001)
    for tid, stream in fleet.items():
        srv.submit(tid, stream)
    srv.run()
    st = srv.stats()["faults"]
    assert st["injected"] == 1 and st["divergences"] >= 1
    assert st["retries"] >= 1 and st["quarantines"] == 0
    t = srv.tenant("t001")
    assert t.done and t.health == "healthy" and len(t.results) == 2
    for tid, stream in fleet.items():
        _assert_matches_solo(srv, tid, stream)


def test_store_poison_rolls_back_lineage_and_recovers():
    """Silent store corruption: the lineage's stored phase-1 snapshot goes
    NaN between ticks (in place — the good bytes are gone).  The next serve
    diverges, the triage finds the stored snapshot non-finite and rolls the
    lineage back to its last-good version (the phase-0 snapshot), so the
    retried phase 2 is bit-identical to a solo stream that runs phase 2
    directly after phase 0."""
    stream = tenant_stream(apps=("KM", "SC"), n_phases=3,
                           n_ops_per_app=N_OPS)
    srv = MappingServer(CFG, n_slots=2, backoff_base_s=0.001)
    srv.submit("t", stream)
    srv.tick()
    srv.tick()                                   # two puts: _prev is armed
    faults.poison_store_agent(srv.store, "t")
    assert not faults.params_finite(srv.store.get("t"))
    srv.run()
    st = srv.stats()["faults"]
    assert st["divergences"] >= 1 and st["rollbacks"] >= 1
    assert srv.store.rollbacks >= 1
    t = srv.tenant("t")
    assert t.done and t.health == "healthy" and len(t.results) == 3
    # phases 0/1 pre-date the corruption: identical to the 3-phase solo
    solo3 = run_stream(solo_stream("t", stream), CFG)
    rolled = run_stream(solo_stream("t", [stream[0], stream[2]]), CFG)
    for pi, want in ((0, solo3.phases[0]), (1, solo3.phases[1]),
                     (2, rolled.phases[1])):
        served = srv.tenant_metrics("t", pi)
        for k in sorted(want.metrics):
            np.testing.assert_array_equal(served[k], want.metrics[k][0],
                                          err_msg=f"phase{pi} {k}")


def test_fail_tick_quarantines_only_target_tenant():
    """Persistent attributed failures exhaust the bounded retry budget and
    quarantine ONLY the failing tenant; its co-tenants drain normally and
    stay bit-identical to their solo runs."""
    fleet = _fleet(3, n_phases=2)
    plan = FaultPlan([FaultEvent("fail_tick", at=i, tenant="t000")
                      for i in range(10)])
    srv = MappingServer(CFG, n_slots=2, faults=plan, max_phase_retries=1,
                        backoff_base_s=0.001)
    for tid, stream in fleet.items():
        srv.submit(tid, stream)
    srv.run()
    st = srv.stats()
    bad = srv.tenant("t000")
    assert bad.quarantined and bad.health == "quarantined"
    assert "injected tick failure" in bad.last_error
    assert st["faults"]["quarantines"] == 1
    assert st["tenants_quarantined"] == 1
    assert st["faults"]["tick_failures"] >= 2     # budget exhausted
    for tid in ("t001", "t002"):
        assert srv.tenant(tid).done
        _assert_matches_solo(srv, tid, fleet[tid])
    # a quarantined id may be resubmitted (fresh stream, same lineage) —
    # with the fault source gone it drains normally
    srv.faults = None
    srv.submit("t000", fleet["t000"])
    srv.run()
    assert srv.tenant("t000").done


def test_unattributed_fail_tick_retries_whole_tick():
    fleet = _fleet(2, n_phases=1)
    plan = FaultPlan([FaultEvent("fail_tick", at=0)])   # tenant=None
    srv = MappingServer(CFG, n_slots=2, faults=plan, backoff_base_s=0.001)
    for tid, stream in fleet.items():
        srv.submit(tid, stream)
    srv.run()
    st = srv.stats()["faults"]
    assert st["tick_failures"] == 1 and st["quarantines"] == 0
    for tid, stream in fleet.items():
        assert srv.tenant(tid).done
        _assert_matches_solo(srv, tid, stream)


def test_stall_attributed_deadline_miss_retries():
    """A host stall attributed to one tenant overruns the per-phase
    deadline: that tenant's attempt is discarded and retried; the final
    results still match the fault-free solo run bit-identically."""
    stream = tenant_stream(apps=("KM",), n_phases=2, n_ops_per_app=N_OPS)
    warmup = MappingServer(CFG, n_slots=2, backoff_base_s=0.001)
    warmup.submit("warmup", stream)
    warmup.run()                        # compile the resident program shapes
    typical = warmup.stats()["phase_latency_p50_s"]
    deadline = max(4 * typical, 0.5)
    plan = FaultPlan([FaultEvent("stall_tick", at=0, tenant="slow",
                                 stall_s=2.5 * deadline)])
    srv = MappingServer(CFG, n_slots=2, backoff_base_s=0.001, faults=plan,
                        phase_deadline_s=deadline)
    srv.submit("slow", stream)
    srv.run()
    st = srv.stats()["faults"]
    assert st["deadline_misses"] >= 1 and st["retries"] >= 1
    t = srv.tenant("slow")
    assert t.done and t.health == "healthy" and len(t.results) == 2
    _assert_matches_solo(srv, "slow", stream)


def test_shrink_devices_mid_service_stays_bit_identical():
    """An injected device-visibility shrink re-places the resident programs
    on the surviving mesh (one recompile) and every tenant's results stay
    bit-identical — the partition layer's sharding invariance, now exercised
    through a failure path.  Real on the forced-4-device CI lane; a
    degenerate (1 -> 1) shrink elsewhere."""
    fleet = _fleet(2, n_phases=3)
    plan = FaultPlan([FaultEvent("shrink_devices", at=1, keep_devices=1)])
    srv = MappingServer(CFG, n_slots=2, faults=plan)
    n_dev0 = partition.mesh_desc(srv.mesh)["n_devices"]
    for tid, stream in fleet.items():
        srv.submit(tid, stream)
    srv.run()
    st = srv.stats()
    assert st["faults"]["device_shrinks"] == 1
    assert st["n_devices"] == 1 and n_dev0 >= 1
    for tid, stream in fleet.items():
        assert srv.tenant(tid).done
        _assert_matches_solo(srv, tid, stream)


# -- crash-safe checkpoint durability -------------------------------------

def _tiny_tree(k=3):
    return {f"w{i}": np.arange(8, dtype=np.float32) * (i + k)
            for i in range(3)}


def test_checkpoint_wait_reraises_async_write_failure(tmp_path,
                                                      monkeypatch):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    import repro.train.checkpoint as ckpt_mod

    def boom(*a, **kw):
        raise OSError("disk on fire")

    monkeypatch.setattr(ckpt_mod.np, "savez", boom)
    mgr.save(0, _tiny_tree())
    with pytest.raises(OSError, match="disk on fire"):
        mgr.wait()
    monkeypatch.undo()
    mgr.save(1, _tiny_tree())                 # the failure does not wedge it
    mgr.wait()
    assert mgr.all_steps() == [1]


def test_checkpoint_meta_records_per_leaf_checksums(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(0, _tiny_tree())
    meta = mgr.read_meta(0)
    for k, rec in meta["leaves"].items():
        assert isinstance(rec["crc32"], int), k
    assert mgr.verify(0)


def test_corrupt_newest_step_falls_back_to_previous(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=0, async_write=False)
    mgr.save(0, _tiny_tree(1))
    mgr.save(1, _tiny_tree(2))
    plan = FaultPlan(seed=11)
    path = plan.corrupt_checkpoint(str(tmp_path), n_bytes=64)
    assert path.endswith("shard_0.npz") and "step_000000001" in path
    assert mgr.newest_intact_step() == 0
    tree, info = mgr.restore(_tiny_tree(9))
    assert info["step"] == 0 and info["fallback_steps_skipped"] == 1
    np.testing.assert_array_equal(np.asarray(tree["w0"]),
                                  _tiny_tree(1)["w0"])
    with pytest.raises(CheckpointCorruptError):
        mgr.restore(_tiny_tree(9), step=1)     # explicit bad step raises
    # corrupted metadata is also detected and skipped
    plan.corrupt_checkpoint(str(tmp_path), step=0, target="meta")
    with pytest.raises(CheckpointCorruptError, match="no intact checkpoint"):
        mgr.restore(_tiny_tree(9))


def test_tampered_leaf_caught_by_checksum(tmp_path):
    """A bit-flip that keeps the npz container valid is invisible to the
    loader — only the recorded per-leaf crc32 catches it."""
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(0, _tiny_tree())
    faults.tamper_leaf(str(tmp_path), 0, "w1")
    arrays, _, bad = mgr.load_arrays(0)
    assert bad == {"w1"} and "w0" in arrays
    assert not mgr.verify(0)
    with pytest.raises(CheckpointCorruptError, match="w1"):
        mgr.restore(_tiny_tree(), step=0)


def test_empty_checkpoint_dir_clear_error(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError, match="no checkpoints"):
        mgr.restore(_tiny_tree())
    with pytest.raises(FileNotFoundError, match="nothing was ever saved"):
        mgr.read_meta()


def test_run_stream_checkpoint_corruption_hook(tmp_path):
    """End to end: a stream whose checkpoint is corrupted after a save (the
    on_checkpoint hook) restores from the newest intact step with the
    fallback counted."""
    acfg = default_agent_cfg(CFG)
    stream = tenant_stream(apps=("KM",), n_phases=2, n_ops_per_app=N_OPS)
    stream = solo_stream("t", stream)
    plan = FaultPlan([FaultEvent("corrupt_checkpoint", at=1, n_bytes=64)],
                     seed=5)
    run_stream(stream, CFG, checkpoint_dir=str(tmp_path), faults=plan)
    assert plan.injected and all(k == "corrupt_checkpoint"
                                 for k, *_ in plan.injected)
    store = PolicyStore.restore(str(tmp_path), acfg)
    assert store.restored_step == 0 and store.restore_fallbacks == 1
    # bit-exact vs the phase-0 store of a fault-free run
    import jax
    clean = run_stream(stream[:1], CFG)
    for la, lb in zip(jax.tree.leaves(store.get("t").params),
                      jax.tree.leaves(clean.store.get("t").params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


_KILL_CHILD = textwrap.dedent("""
    import sys
    import numpy as np
    from repro.core.agent import cold_start
    from repro.nmp import NMPConfig
    from repro.nmp.continual import PolicyStore
    from repro.nmp.engine import default_agent_cfg

    directory = sys.argv[1]
    acfg = default_agent_cfg(NMPConfig())
    store = PolicyStore()
    for k in range(200):
        store.put("t", cold_start(k, acfg))
        store.save(directory, step=k)
        print(k, flush=True)
""")


def test_kill_resume_restores_newest_intact_step(tmp_path):
    """Crash safety at any byte boundary: SIGKILL a process mid-save loop,
    then restore — the newest committed step restores bit-exactly (it is
    the deterministic cold_start of its own step index), and every printed
    (= committed) step is still available."""
    env = dict(os.environ, PYTHONPATH="src",
               JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"))
    proc = subprocess.Popen([sys.executable, "-c", _KILL_CHILD,
                             str(tmp_path)], stdout=subprocess.PIPE,
                            text=True, env=env, cwd="/root/repo")
    printed = []
    deadline = time.monotonic() + 120
    while len(printed) < 3 and time.monotonic() < deadline:
        line = proc.stdout.readline()
        if line.strip().isdigit():
            printed.append(int(line))
    assert len(printed) >= 3, "child never completed 3 saves"
    proc.send_signal(signal.SIGKILL)
    proc.wait()
    acfg = default_agent_cfg(CFG)
    store = PolicyStore.restore(str(tmp_path), acfg)
    last_printed = printed[-1]
    assert store.restored_step >= last_printed
    assert store.corrupt_tags == []
    # the stored agent at step k is cold_start(k): bit-exact check
    import jax
    want = agent_mod.export_agent(
        agent_mod.cold_start(store.restored_step, acfg))
    got = store.get("t")
    for wa, ga in zip(jax.tree.leaves(want.params),
                      jax.tree.leaves(got.params)):
        np.testing.assert_array_equal(np.asarray(wa), np.asarray(ga))
    # an explicitly requested committed earlier step also restores
    older = PolicyStore.restore(str(tmp_path), acfg, step=printed[0])
    assert older.restored_step == printed[0]
