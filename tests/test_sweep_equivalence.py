"""Batched sweep vs serial engine: per-scenario metrics must match
bit-for-bit, including lanes whose traces are shorter than the batch
envelope (op-count and page-count padding) and scenarios folded onto a
vmapped seed axis (seed replicas of a cell share one lane and one copy of
its trace arrays — see nmp.plan).

Grids are sized so related checks share one compiled sweep signature
(same op/page envelope, episode count and agent mode => one XLA program).
"""
import numpy as np
import pytest

from repro.nmp import NMPConfig, make_trace
from repro.nmp.engine import run_episode, run_program
from repro.nmp.scenarios import (Scenario, forced_action_grid, seed_variants,
                                 single_program_grid)
from repro.nmp.stats import summarize
from repro.nmp.sweep import run_grid

CFG = NMPConfig()


def _assert_exact(serial: dict, batched: dict, label: str):
    for key in ("cycles", "ops", "opc"):
        assert serial[key] == batched[key], (label, key, serial[key],
                                             batched[key])


def test_grid_matches_serial_deterministic_lanes():
    """Mixed apps (different n_ops AND n_pages => padding exercised), mixed
    mappers {none, tom} and mixed techniques, one batched program: every lane
    reproduces its serial run_episode exactly."""
    grid = []
    for app, n_ops in (("KM", 384), ("RBM", 512), ("MAC", 640)):
        tr = make_trace(app, n_ops=n_ops)
        for mapper in ("none", "tom"):
            grid.append(Scenario(name=f"{app}/{mapper}", trace=tr,
                                 mapper=mapper))
    for tech in ("ldb", "pei"):
        grid.append(Scenario(name=f"KM/{tech}", trace=grid[0].trace,
                             technique=tech))
    res = run_grid(grid, CFG)
    for i, sc in enumerate(grid):
        serial = summarize(run_episode(sc.trace, CFG, sc.technique, sc.mapper,
                                       seed=sc.seed))
        _assert_exact(serial, res.episode_summary(i, 0), sc.name)
        assert res.episode_summary(i, 0)["ops"] == sc.trace.n_ops


@pytest.mark.slow
def test_grid_matches_serial_aimm_chained_episodes():
    """Multi-episode AIMM lanes (DQN persisted across the in-scan episode
    chain) match run_program per episode, even with op-count padding; the
    stacked final env stays physically valid."""
    grid = []
    for app, n_ops in (("KM", 384), ("SPMV", 768)):
        grid.append(Scenario(name=app, trace=make_trace(app, n_ops=n_ops),
                             mapper="aimm", episodes=2))
    res = run_grid(grid, CFG)
    for i, sc in enumerate(grid):
        serial = run_program(sc.trace, CFG, sc.technique, "aimm",
                             episodes=sc.episodes, seed=sc.seed)
        for e in range(sc.episodes):
            _assert_exact(summarize(serial[e]), res.episode_summary(i, e),
                          f"{sc.name}/ep{e}")
    p2c = np.asarray(res.final_env.page_to_cube)
    assert (p2c >= 0).all() and (p2c < CFG.n_cubes).all()
    assert res.metrics["cycles"].shape == (len(grid), res.n_episodes)


def test_grid_matches_serial_forced_actions():
    """Scripted-policy lanes (no DQN) match serial forced_action runs."""
    grid = forced_action_grid(app="KM", n_ops=384, actions=(0, 1, 5))
    res = run_grid(grid, CFG)
    for i, sc in enumerate(grid):
        serial = summarize(run_episode(sc.trace, CFG, sc.technique, "aimm",
                                       forced_action=sc.forced_action,
                                       seed=sc.seed))
        _assert_exact(serial, res.episode_summary(i, 0), sc.name)


def test_seed_folded_grid_matches_serial():
    """18+-cell grid with 3 seeds per cell: the plan layer folds the seed
    replicas onto a vmapped seed axis (9 lanes, not 27), and every
    (lane, seed) cell still reproduces its serial run bit-for-bit —
    including the scripted-AIMM cells, whose trajectories genuinely depend
    on the seed through the env RNG."""
    grid = []
    for app, n_ops in (("KM", 384), ("RBM", 512), ("MAC", 640)):
        tr = make_trace(app, n_ops=n_ops)
        for mapper, forced in (("none", -1), ("tom", -1), ("aimm", 1)):
            grid += seed_variants(
                Scenario(name=f"{app}/{mapper}", trace=tr, mapper=mapper,
                         forced_action=forced), seeds=(0, 1, 2))
    assert len(grid) == 27
    res = run_grid(grid, CFG)
    assert res.plan.n_lanes == 9            # 27 cells folded 3-to-1
    assert [g.n_seeds for g in res.plan.groups] == [3]
    for i, sc in enumerate(grid):
        serial = summarize(run_episode(sc.trace, CFG, sc.technique, sc.mapper,
                                       seed=sc.seed,
                                       forced_action=sc.forced_action))
        _assert_exact(serial, res.episode_summary(i, 0), f"{sc.name}/s{sc.seed}")
    # the scripted lanes' seeds must actually matter (env RNG drives the
    # random-neighbor action target), otherwise the band test is vacuous
    aimm0 = [i for i, sc in enumerate(grid)
             if sc.mapper == "aimm" and sc.trace.n_ops == 640]
    cyc = {res.episode_summary(i, 0)["cycles"] for i in aimm0}
    assert len(cyc) > 1


def test_variance_band_over_folded_seeds():
    tr = make_trace("SPMV", n_ops=384)
    grid = seed_variants(Scenario(name="SPMV/forced", trace=tr, mapper="aimm",
                                  forced_action=1), seeds=(0, 1, 2))
    res = run_grid(grid, CFG)
    assert res.seed_group(1) == [0, 1, 2]
    band = res.variance_band(0)
    assert band["n"] == 3 and band["seeds"] == [0, 1, 2]
    opcs = np.asarray([res.episode_summary(i, 0)["opc"] for i in range(3)])
    np.testing.assert_allclose(band["opc_mean"], opcs.mean())
    np.testing.assert_allclose(band["opc_std"], opcs.std())
    mean_tl, std_tl = res.opc_timeline_band(0)
    assert mean_tl.shape == std_tl.shape == (64,)
    assert (std_tl >= 0).all()


@pytest.mark.slow
def test_seed_folded_aimm_chained_matches_run_program():
    """Learned-policy lanes with a folded seed axis: every (seed, episode)
    cell of the in-scan episode chain matches its serial run_program — the
    per-seed DQNs train independently inside one compiled program."""
    tr = make_trace("KM", n_ops=384)
    grid = seed_variants(Scenario(name="KM/aimm", trace=tr, mapper="aimm",
                                  episodes=2), seeds=(0, 1, 2))
    res = run_grid(grid, CFG)
    assert res.plan.n_lanes == 1 and res.plan.groups[0].n_seeds == 3
    for i, sc in enumerate(grid):
        serial = run_program(sc.trace, CFG, sc.technique, "aimm",
                             episodes=sc.episodes, seed=sc.seed)
        for e in range(sc.episodes):
            _assert_exact(summarize(serial[e]), res.episode_summary(i, e),
                          f"s{sc.seed}/ep{e}")


def test_single_program_grid_builder_covers_cells():
    grid = single_program_grid(apps=("KM", "RBM"), mappers=("none", "aimm"),
                               n_ops=256, seeds=(0, 1))
    assert len(grid) == 2 * 2 * 2
    names = {sc.name for sc in grid}
    assert len(names) == len(grid)          # unique lane names
