"""Plan + partition layers of the sweep pipeline.

Unit tests cover seed folding / lane grouping / padding arithmetic directly;
the multi-device path (lane-axis `NamedSharding` over a forced 4-device host
platform, including non-divisible lane-count padding) runs in a subprocess
because `XLA_FLAGS=--xla_force_host_platform_device_count=4` must be set
before jax initializes.  The same path runs in-process for the whole suite
on the CI job that exports that flag globally (see .github/workflows/ci.yml).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.nmp import NMPConfig, make_trace
from repro.nmp import partition
from repro.nmp.plan import build_group_batch, plan_grid
from repro.nmp.scenarios import Scenario, seed_variants

CFG = NMPConfig()


def _mixed_grid():
    grid = []
    for app, n_ops in (("KM", 384), ("RBM", 512)):
        tr = make_trace(app, n_ops=n_ops)
        for mapper in ("none", "tom"):
            grid += seed_variants(Scenario(name=f"{app}/{mapper}", trace=tr,
                                           mapper=mapper), seeds=(0, 1, 2))
    tr = make_trace("MAC", n_ops=384)
    grid += seed_variants(Scenario(name="MAC/aimm", trace=tr, mapper="aimm",
                                   episodes=2), seeds=(0, 1))
    return grid


# ---------------------------------------------------------------------------
# Plan layer
# ---------------------------------------------------------------------------

def test_plan_folds_seeds_and_groups_lanes():
    grid = _mixed_grid()
    plan = plan_grid(grid, CFG)
    assert len(plan.groups) == 2
    agent, det = plan.groups
    assert agent.has_agent and not det.has_agent
    assert (agent.n_lanes, agent.n_seeds) == (1, 2)
    # 12 deterministic cells fold 3-to-1 AND collapse their seed axis: the
    # deterministic mappers are seed-invariant, so one simulated cell per
    # lane serves all three replicas
    assert (det.n_lanes, det.n_seeds) == (4, 1)
    assert all(ln.slots == (0, 0, 0) for ln in det.lanes)
    assert det.flags.any_tom and not det.flags.has_agent
    # the index map covers every scenario exactly once
    seen = sorted(i for g in plan.groups for ln in g.lanes
                  for i in ln.indices)
    assert seen == list(range(len(grid)))
    assert plan.seed_group(1) == (0, 1, 2)
    # envelope: padded to the largest trace / longest schedule
    assert plan.n_ops_max == 512 and plan.n_episodes == 2


def test_plan_pads_ragged_seed_axes():
    """Seed-variant lanes with different seed counts share one group: the
    narrow lane's seed axis is padded by re-simulating its first seed."""
    tr = make_trace("KM", n_ops=384)
    grid = (seed_variants(Scenario(name="a", trace=tr, mapper="aimm",
                                   forced_action=1), seeds=(0, 1, 2))
            + [Scenario(name="b", trace=tr, mapper="aimm", forced_action=3,
                        seed=7)])
    plan = plan_grid(grid, CFG)
    (group,) = plan.groups
    assert group.n_seeds == 3
    narrow = group.lanes[1]
    assert narrow.seeds == (7, 7, 7) and narrow.indices == (3,)
    assert narrow.slots == (0,)
    batch = build_group_batch(plan, group, CFG)
    assert batch["ep_seed"].shape == (2, 3, 1)
    assert (batch["ep_seed"][1, :, 0] == 7).all()


def test_distinct_trace_objects_do_not_fold():
    """Folding keys on Trace object identity: equal-seed scenarios over
    different traces stay separate lanes."""
    grid = [Scenario(name="a", trace=make_trace("KM", n_ops=384)),
            Scenario(name="b", trace=make_trace("KM", n_ops=384))]
    plan = plan_grid(grid, CFG)
    assert plan.n_lanes == 2


def test_plan_groups_warm_lineage_lanes_apart_from_cold():
    """Lineage (warm-capable) agent lanes compile separately from plain
    cold-start agent lanes: cold group first (the exact historical program),
    then the lineage group, then deterministic lanes — and GridPlan records
    the per-scenario lineage map."""
    tr = make_trace("KM", n_ops=384)
    grid = [
        Scenario(name="cold", trace=tr, mapper="aimm"),
        Scenario(name="warm", trace=tr, mapper="aimm", lineage="tagA"),
        Scenario(name="det", trace=tr, mapper="tom"),
        Scenario(name="warm2", trace=tr, mapper="aimm", lineage="tagB",
                 seed=1),
    ]
    plan = plan_grid(grid, CFG)
    assert [(g.has_agent, g.lineage, g.n_lanes) for g in plan.groups] == [
        (True, False, 1), (True, True, 2), (False, False, 1)]
    assert plan.agent_lineage == (None, "tagA", None, "tagB")
    assert plan.lineage_tags() == ("tagA", "tagB")
    # lineage is part of the fold key: same trace/seed, different tag => no fold
    assert all(len(ln.indices) == 1 for g in plan.groups for ln in g.lanes)


def test_plan_lineage_on_non_agent_lane_is_inert():
    """A lineage tag on a deterministic or scripted lane carries no agent:
    the plan normalizes it away instead of spawning a warm group."""
    tr = make_trace("KM", n_ops=384)
    grid = [Scenario(name="det", trace=tr, mapper="tom", lineage="t"),
            Scenario(name="scripted", trace=tr, mapper="aimm",
                     forced_action=1, lineage="t")]
    plan = plan_grid(grid, CFG)
    assert all(not g.lineage for g in plan.groups)
    assert plan.agent_lineage == (None, None)
    assert plan.lineage_tags() == ()


def test_plan_lineage_seed_variants_fold_into_one_warm_lane():
    """Seed replicas of one lineage-tagged cell still fold onto the seed
    axis (they share the tag and the fold key)."""
    tr = make_trace("KM", n_ops=384)
    grid = seed_variants(Scenario(name="w", trace=tr, mapper="aimm",
                                  lineage="t"), seeds=(0, 1, 2))
    plan = plan_grid(grid, CFG)
    (group,) = plan.groups
    assert group.lineage and group.n_lanes == 1 and group.n_seeds == 3


def test_plan_rejects_invalid_lineage_tags_at_plan_time():
    """A malformed tag must fail before anything compiles or simulates, not
    in the post-run store write-back."""
    tr = make_trace("KM", n_ops=384)
    for bad in ("", "a/b"):
        with pytest.raises(ValueError, match="lineage tag"):
            plan_grid([Scenario(name="x", trace=tr, mapper="aimm",
                                lineage=bad)], CFG)


def test_plan_rejects_ragged_lineage_episode_counts():
    """Padding episodes would over-train a lineage's agent past its schedule;
    ragged lineage groups must be refused, not silently padded."""
    tr = make_trace("KM", n_ops=384)
    grid = [Scenario(name="a", trace=tr, mapper="aimm", lineage="t",
                     episodes=1),
            Scenario(name="b", trace=tr, mapper="aimm", lineage="u",
                     episodes=3)]
    with pytest.raises(ValueError, match="episode count"):
        plan_grid(grid, CFG)
    # cold lanes keep the historical pad-to-max behavior
    cold = [Scenario(name="a", trace=tr, mapper="aimm", episodes=1),
            Scenario(name="b", trace=tr, mapper="aimm", episodes=3)]
    assert plan_grid(cold, CFG).groups[0].n_episodes == 3


def test_empty_grid_raises_clear_error():
    """`run_grid([])` historically died with a bare IndexError deep in the
    plan layer; an empty grid (or an empty stream phase) must fail at
    `plan_grid` with an actionable message instead."""
    from repro.nmp.continual import run_stream
    from repro.nmp.plan import plan_envelope
    from repro.nmp.sweep import run_grid
    with pytest.raises(ValueError, match="empty scenario grid"):
        plan_grid([], CFG)
    with pytest.raises(ValueError, match="empty scenario grid"):
        run_grid([], CFG)
    with pytest.raises(ValueError, match="empty scenario grid"):
        run_stream([[]], CFG)               # a stream with an empty phase
    with pytest.raises(ValueError, match="empty scenario grid"):
        plan_envelope([], CFG)


def test_envelope_dominance_and_forced_plan():
    """A forced envelope must dominate the grid's own; when it does, its
    padded dims replace the derived ones (the serving layer's fixed-shape
    contract) — and episode padding of lineage lanes is still refused."""
    from repro.nmp.plan import Envelope, plan_envelope
    small = make_trace("KM", n_ops=256)
    big = make_trace("KM", n_ops=512)
    need = plan_envelope([Scenario(name="s", trace=small, mapper="aimm")],
                         CFG)
    env = plan_envelope([Scenario(name="b", trace=big, mapper="aimm",
                                  episodes=1)], CFG)
    assert env.dominates(need) and not need.dominates(env)
    forced = plan_grid([Scenario(name="s", trace=small, mapper="aimm")],
                       CFG, envelope=env)
    assert (forced.n_ops_max, forced.n_pages_max) == (env.n_ops_max,
                                                      env.n_pages_max)
    assert forced.n_epochs == env.n_epochs
    assert forced.groups[0].n_episodes == env.n_episodes
    with pytest.raises(ValueError, match="does not cover"):
        plan_grid([Scenario(name="b", trace=big, mapper="aimm")], CFG,
                  envelope=need)
    # a forced envelope must not pad a lineage lane's episode schedule
    wide = dataclasses.replace(env, n_episodes=3)
    with pytest.raises(ValueError, match="past its schedule"):
        plan_grid([Scenario(name="s", trace=small, mapper="aimm",
                            lineage="t", episodes=1)], CFG, envelope=wide)
    # ...but cold lanes simply pad (no agent schedule to corrupt)
    cold = plan_grid([Scenario(name="s", trace=small, mapper="none")], CFG,
                     envelope=wide)
    assert cold.groups[0].n_episodes == 3


# ---------------------------------------------------------------------------
# Partition layer
# ---------------------------------------------------------------------------

def test_single_device_degrades_to_no_mesh():
    assert partition.build_mesh([object()]) is None
    assert partition.mesh_desc(None)["n_devices"] == 1
    assert partition.padded_lane_count(5, None) == 5


def test_pad_group_batch_repeats_lane_zero():
    batch = {"x": np.arange(6).reshape(3, 2), "y": np.arange(3)}
    out = partition.pad_group_batch(batch, 4)
    assert out["x"].shape == (4, 2) and out["y"].shape == (4,)
    np.testing.assert_array_equal(out["x"][3], batch["x"][0])
    same = partition.pad_group_batch(batch, 3)
    assert same["x"].shape == (3, 2)


def test_pad_group_batch_rejects_empty_batch():
    """An empty group batch used to escape as a bare StopIteration from
    `next(iter(...))` (which a surrounding generator would silently swallow
    as exhaustion); it must be a clear ValueError."""
    with pytest.raises(ValueError, match="empty group batch"):
        partition.pad_group_batch({}, 4)


def test_sweep_devices_env_validation(monkeypatch):
    monkeypatch.setenv("REPRO_SWEEP_DEVICES", "banana")
    with pytest.raises(ValueError, match="REPRO_SWEEP_DEVICES"):
        partition.sweep_devices()
    monkeypatch.setenv("REPRO_SWEEP_DEVICES", "0")
    with pytest.raises(ValueError, match="outside"):
        partition.sweep_devices()
    monkeypatch.setenv("REPRO_SWEEP_DEVICES", "99")
    with pytest.raises(ValueError, match="outside"):
        partition.sweep_devices()
    monkeypatch.setenv("REPRO_SWEEP_DEVICES", "all")
    assert len(partition.sweep_devices()) >= 1


def test_sweep_mesh_env_validation(monkeypatch):
    """REPRO_SWEEP_MESH misuse must raise a ValueError naming the knob, the
    value, and the devices — never an opaque mesh-construction error."""
    for bad in ("banana", "2x2x2", "4", "0x4", "2x-2"):
        monkeypatch.setenv("REPRO_SWEEP_MESH", bad)
        with pytest.raises(ValueError, match="REPRO_SWEEP_MESH"):
            partition.sweep_mesh_shape(4)
    # a shape that doesn't factor the selected device count
    monkeypatch.setenv("REPRO_SWEEP_MESH", "3x2")
    with pytest.raises(ValueError) as ei:
        partition.sweep_mesh_shape(4)
    msg = str(ei.value)
    assert "REPRO_SWEEP_MESH" in msg and "3x2" in msg
    assert "6 devices" in msg and "4 device(s)" in msg
    # valid shapes parse; ""/"auto" defer to auto-factoring
    monkeypatch.setenv("REPRO_SWEEP_MESH", "2x2")
    assert partition.sweep_mesh_shape(4) == (2, 2)
    for auto in ("", "auto"):
        monkeypatch.setenv("REPRO_SWEEP_MESH", auto)
        assert partition.sweep_mesh_shape(4) is None


def test_auto_mesh_shape_minimizes_padded_cells():
    # all-S=1 plans keep the historical 1-D lane mesh
    assert partition.auto_mesh_shape(4, [(8, 1, 2)]) == (4, 1)
    # a seed-wide 2-lane group wants the seed axis sharded
    assert partition.auto_mesh_shape(4, [(2, 8, 2)]) in ((2, 2), (1, 4))
    assert partition.auto_mesh_shape(4, [(2, 8, 2), (2, 1, 1)]) == (2, 2)
    assert partition.auto_mesh_shape(1, [(3, 2, 1)]) == (1, 1)


# ---------------------------------------------------------------------------
# Sharded execution (forced 4-device host platform, subprocess)
# ---------------------------------------------------------------------------

_SHARDED_SCRIPT = textwrap.dedent("""
    import os
    import numpy as np
    import jax
    assert jax.device_count() == 4, jax.devices()

    from repro.nmp import NMPConfig, make_trace
    from repro.nmp.scenarios import Scenario, seed_variants
    from repro.nmp.sweep import run_grid

    cfg = NMPConfig()
    grid = []
    for app, n_ops in (("KM", 256), ("RBM", 384)):
        tr = make_trace(app, n_ops=n_ops)
        for mapper in ("none", "tom"):
            grid += seed_variants(
                Scenario(name=f"{app}/{mapper}", trace=tr, mapper=mapper),
                seeds=(0, 1, 2))
    tr = make_trace("MAC", n_ops=256)
    grid += seed_variants(
        Scenario(name="MAC/forced", trace=tr, mapper="aimm",
                 forced_action=1), seeds=(0, 1, 2))

    os.environ["REPRO_SWEEP_DEVICES"] = "1"
    r1 = run_grid(grid, cfg)
    os.environ["REPRO_SWEEP_DEVICES"] = "4"
    r4 = run_grid(grid, cfg)
    assert (r1.n_devices, r4.n_devices) == (1, 4)
    # 5 folded lanes shard over 4 devices only after padding to 8
    assert r4.plan.n_lanes == 5
    for k in sorted(r1.metrics):
        np.testing.assert_array_equal(r1.metrics[k], r4.metrics[k], err_msg=k)
    print("SHARDED-OK")
""")


@pytest.mark.slow
def test_sharded_grid_bit_identical_on_forced_host_devices():
    """The same grid, single-device vs sharded over 4 forced host devices:
    per-cell metrics must match bit-for-bit (per-lane work never crosses a
    device; the only collectives are the boolean any-lane cond gates), with
    the 5-lane group padded up to the device-divisible 8."""
    env = dict(
        os.environ,
        XLA_FLAGS=("--xla_force_host_platform_device_count=4 "
                   + os.environ.get("XLA_FLAGS", "")),
        JAX_PLATFORMS="cpu",
    )
    env.pop("REPRO_SWEEP_DEVICES", None)
    proc = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED-OK" in proc.stdout
