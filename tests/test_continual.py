"""Continual-learning lifecycle layer: PolicyStore warm starts, lifetime
exploration decay, checkpoint/restore bit-exactness, and program-switch
streams (nmp.continual + the sweep's lineage groups).

The cold-start path is covered by the golden + sweep-equivalence suites; the
tests here pin the *new* semantics: a lineage's DQN carries across run_grid
calls (weights, replay, Adam moments, RNG, global_step), exploration decays
over the agent's lifetime instead of restarting per scenario, and a store
checkpointed mid-stream restores — in a fresh process — to reproduce the
remaining stream bit-exactly.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import agent as A
from repro.nmp import NMPConfig, make_trace
from repro.nmp.continual import PolicyStore, run_stream
from repro.nmp.engine import default_agent_cfg
from repro.nmp.scenarios import Scenario, build_stream, continual_stream
from repro.nmp.sweep import run_grid

CFG = NMPConfig()
ACFG = default_agent_cfg(CFG)


def _leaves_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               and np.asarray(x).dtype == np.asarray(y).dtype
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# Agent lifecycle primitives
# ---------------------------------------------------------------------------

def test_cold_start_counters_and_template_structure():
    ag = A.cold_start(3, ACFG)
    assert int(ag.step) == int(ag.global_step) == 0
    tmpl = A.agent_template(ACFG)
    assert (jax.tree_util.tree_structure(ag)
            == jax.tree_util.tree_structure(tmpl))
    for a, t in zip(jax.tree.leaves(ag), jax.tree.leaves(tmpl)):
        assert a.shape == t.shape and a.dtype == t.dtype


def test_hand_off_resets_scenario_counter_keeps_lifetime():
    ag = A.cold_start(0, ACFG)
    _, ag = A.act(ag, ACFG, jnp.zeros(ACFG.dqn.state_dim))
    _, ag = A.act(ag, ACFG, jnp.zeros(ACFG.dqn.state_dim))
    assert int(ag.step) == int(ag.global_step) == 2
    ho = A.hand_off(ag)
    assert int(ho.step) == 0 and int(ho.global_step) == 2
    assert _leaves_equal(ho.params, ag.params)
    assert _leaves_equal(ho.replay, ag.replay)
    np.testing.assert_array_equal(np.asarray(ho.rng), np.asarray(ag.rng))


def test_epsilon_decays_over_lifetime_not_per_scenario():
    """The ε schedule keys on global_step: after a handoff the agent keeps
    exploiting instead of rewinding to eps_start (the satellite fix — the
    historical schedule restarted with every scenario)."""
    ag = A.cold_start(0, ACFG)
    eps0 = float(A.epsilon(ACFG, ag.global_step))
    for _ in range(60):
        _, ag = A.act(ag, ACFG, jnp.zeros(ACFG.dqn.state_dim))
    ag = A.hand_off(ag)                      # scenario boundary
    eps_warm = float(A.epsilon(ACFG, ag.global_step))
    assert eps_warm < eps0                   # no reset to eps_start
    assert np.isclose(eps0, ACFG.eps_start)


def test_store_put_get_checkout_and_tag_validation():
    store = PolicyStore()
    ag = A.cold_start(0, ACFG)
    _, ag = A.act(ag, ACFG, jnp.zeros(ACFG.dqn.state_dim))
    store.put("km", ag, scenario="KM")
    assert "km" in store and store.tags == ["km"] and len(store) == 1
    assert store.global_step("km") == 1
    assert store.meta["km"]["scenario"] == "KM"
    got = store.checkout("km")
    assert int(got.step) == 0 and int(got.global_step) == 1
    assert _leaves_equal(got.params, ag.params)
    for bad in ("", "a/b", 7):
        with pytest.raises(ValueError, match="lineage tag"):
            store.put(bad, ag)


def test_store_capacity_lru_eviction_and_versioning():
    """A bounded store evicts the least-recently-used lineage on overflow;
    `put` and `checkout` both refresh recency, the just-put tag is never the
    victim (capacity=1 works), and a tag's `version` keeps counting across
    eviction so a returning lineage is observably a later incarnation."""
    with pytest.raises(ValueError, match="capacity"):
        PolicyStore(capacity=0)
    ag = A.cold_start(0, ACFG)
    store = PolicyStore(capacity=2)
    store.put("a", ag)
    store.put("b", ag)
    store.checkout("a")                      # recency now: b < a
    store.put("c", ag)                       # overflow -> evict LRU "b"
    assert store.tags == ["a", "c"] and "b" not in store
    assert store.evictions == 1
    assert store.meta["b"]["evicted"] == 1   # provenance survives eviction
    store.put("b", ag)                       # returning tag -> evict "a"
    assert store.tags == ["b", "c"]
    assert store.version("b") == 2           # version continued across evict
    # capacity=1: every put displaces the previous resident, never itself
    one = PolicyStore(capacity=1)
    for t in ("x", "y", "x"):
        one.put(t, ag)
    assert one.tags == ["x"] and one.evictions == 2
    # a pre-populated over-capacity store trims on construction
    trimmed = PolicyStore(agents={"a": A.export_agent(ag),
                                  "b": A.export_agent(ag)}, capacity=1)
    assert len(trimmed) == 1


def test_store_capacity_and_evictions_survive_checkpoint(tmp_path):
    """save/restore round-trips the capacity bound and the lifetime eviction
    counter, and the restored store remembers its checkpoint step (the hook
    run_stream uses to realign resumed histories)."""
    ag = A.cold_start(0, ACFG)
    store = PolicyStore(capacity=2)
    for t in ("a", "b", "c"):
        store.put(t, ag)
    step = store.save(str(tmp_path))
    back = PolicyStore.restore(str(tmp_path), ACFG, step=step)
    assert back.capacity == 2 and back.evictions == 1
    assert back.tags == store.tags
    assert back.restored_step == step and store.restored_step is None


# ---------------------------------------------------------------------------
# Warm-start grids
# ---------------------------------------------------------------------------

def _phase(tr, name, lineage="t", episodes=1):
    return [Scenario(name=name, trace=tr, mapper="aimm", episodes=episodes,
                     lineage=lineage)]


def test_run_grid_threads_lineage_through_store():
    tr = make_trace("KM", n_ops=384)
    r1 = run_grid(_phase(tr, "p0"), CFG)
    store = r1.store
    assert store is not None and store.tags == ["t"]
    gs1 = store.global_step("t")
    assert gs1 == r1.invocations(0) > 0
    r2 = run_grid(_phase(tr, "p1"), CFG, store=store)
    assert r2.store is store                 # updated in place
    assert store.global_step("t") == gs1 + r2.invocations(0)
    assert store.meta["t"]["phases"] == 2
    assert store.meta["t"]["scenario"] == "p1"


def test_warm_start_changes_trajectory_cold_grid_has_no_store():
    """A warm-started lane must actually differ from a cold lane of the same
    scenario (the carried DQN/replay/ε change decisions), and a grid without
    lineages must not grow a store."""
    tr = make_trace("KM", n_ops=384)
    store = run_grid(_phase(tr, "p0", episodes=2), CFG).store
    warm = run_grid(_phase(tr, "p1"), CFG, store=store)
    cold = run_grid(_phase(tr, "p1"), CFG)   # fresh store => cold lineage
    assert (warm.metrics["cycles"][0, 0] != cold.metrics["cycles"][0, 0]
            or warm.invocations(0) != cold.invocations(0))
    plain = run_grid([Scenario(name="km", trace=tr, mapper="aimm")], CFG)
    assert plain.store is None


def test_fresh_lineage_matches_inline_cold_start_bitwise():
    """A lineage lane whose tag is absent cold-starts the lineage: the warm-
    capable program (agent batch passed in) must reproduce the historical
    in-jit cold start bit-for-bit for the same scenario."""
    tr = make_trace("KM", n_ops=384)
    lin = run_grid(_phase(tr, "km", episodes=2), CFG)
    cold = run_grid([Scenario(name="km", trace=tr, mapper="aimm",
                              episodes=2)], CFG)
    for k in ("cycles", "ops", "opc_t", "invoke_t"):
        np.testing.assert_array_equal(lin.metrics[k], cold.metrics[k],
                                      err_msg=k)


def test_run_stream_equals_manual_chained_run_grids():
    stream = build_stream("switch", n_ops_per_app=384, episodes=1,
                          include_baseline=False)
    res = run_stream(stream, CFG)
    store = PolicyStore()
    for pi, phase in enumerate(stream):
        manual = run_grid(phase, CFG, store=store)
        for k in ("cycles", "ops", "opc_t"):
            np.testing.assert_array_equal(res.phases[pi].metrics[k],
                                          manual.metrics[k], err_msg=k)
    assert store.global_step("stream") == res.store.global_step("stream")


def test_continual_stream_builder_shapes():
    stream = continual_stream(n_ops_per_app=256, episodes=2)
    assert len(stream) == 3
    for phase in stream:
        assert [sc.mapper for sc in phase] == ["none", "aimm"]
        assert phase[1].lineage == "stream"
    # co-runner phase merges per-app traces, single phases reuse them
    assert stream[1][1].trace.n_ops == 512
    assert stream[0][1].trace is stream[0][0].trace
    names = [sc.name for phase in stream for sc in phase]
    assert len(set(names)) == len(names)


# ---------------------------------------------------------------------------
# Checkpoint / restore
# ---------------------------------------------------------------------------

def test_store_checkpoint_roundtrip_bit_exact(tmp_path):
    """Every AgentState leaf — replay buffer (f32/i32), Adam moments, the
    uint32 PRNG key, counters — survives save/restore bit-exactly, via an
    RNG-free template in the restoring process."""
    tr = make_trace("KM", n_ops=384)
    store = run_grid(_phase(tr, "p0", episodes=2), CFG).store
    step = store.save(str(tmp_path))
    back = PolicyStore.restore(str(tmp_path), ACFG, step=step)
    a, b = store.get("t"), back.get("t")
    assert _leaves_equal(a, b)
    assert np.asarray(b.rng).dtype == np.uint32
    assert np.asarray(b.replay.a).dtype == np.int32
    assert np.asarray(b.opt_state["m"]["w0"]).dtype == np.float32
    assert back.meta["t"]["global_step"] == store.global_step("t")
    # repeated saves form a history; default step continues it, and every
    # step is kept (keep=0) — each phase of a stream must stay a valid
    # resume point, beyond CheckpointManager's default retention of 3
    assert store.save(str(tmp_path)) == step + 1
    for _ in range(3):
        store.save(str(tmp_path))
    from repro.train.checkpoint import CheckpointManager
    assert CheckpointManager(str(tmp_path)).all_steps() == [0, 1, 2, 3, 4]
    assert _leaves_equal(
        PolicyStore.restore(str(tmp_path), ACFG, step=0).get("t"), a)


def test_resume_from_older_step_realigns_checkpoint_history(tmp_path):
    """The stop/resume bugfix: resuming a checkpointed stream from an older
    step `k` must write the re-run phases at `k+1, k+2, ...` — overwriting
    the now-stale later steps — not append them at `latest+1`, which left
    the directory's step <-> phase mapping silently misaligned."""
    ck = str(tmp_path / "ck")
    stream = build_stream("switch", n_ops_per_app=384, episodes=1,
                          include_baseline=False)
    full = run_stream(stream, CFG, checkpoint_dir=ck)
    from repro.train.checkpoint import CheckpointManager
    assert CheckpointManager(ck).all_steps() == [0, 1, 2]

    # resume from step 0 (end of phase 0) and re-run phases 1..2
    store = PolicyStore.restore(ck, ACFG, step=0)
    res = run_stream(stream[1:], CFG, store=store, checkpoint_dir=ck)
    # steps 1 and 2 were overwritten in place — nothing appended at 3, 4
    assert CheckpointManager(ck).all_steps() == [0, 1, 2]
    for pi in (0, 1):
        for k in ("cycles", "ops", "opc_t"):
            np.testing.assert_array_equal(res.phases[pi].metrics[k],
                                          full.phases[pi + 1].metrics[k],
                                          err_msg=f"phase{pi + 1} {k}")
    # step 2 now again holds the end-of-stream store, bit-exactly
    assert _leaves_equal(
        PolicyStore.restore(ck, ACFG, step=2).get("stream"),
        full.store.get("stream"))
    # an explicit base step wins over the restored-step default
    run_stream(stream[2:], CFG, store=PolicyStore.restore(ck, ACFG, step=1),
               checkpoint_base_step=7, checkpoint_dir=ck)
    assert CheckpointManager(ck).all_steps() == [0, 1, 2, 7]


_RESUME_SCRIPT = textwrap.dedent("""
    import sys
    import numpy as np
    from repro.nmp import NMPConfig
    from repro.nmp.continual import PolicyStore, run_stream
    from repro.nmp.engine import default_agent_cfg
    from repro.nmp.scenarios import build_stream

    ckpt_dir, out = sys.argv[1], sys.argv[2]
    cfg = NMPConfig()
    stream = build_stream("switch", n_ops_per_app=384, episodes=1,
                          include_baseline=False)
    store = PolicyStore.restore(ckpt_dir, default_agent_cfg(cfg), step=1)
    res = run_stream(stream[2:], cfg, store=store)
    np.savez(out, **{k: v for k, v in res.phases[0].metrics.items()})
    print("RESUME-OK")
""")


@pytest.mark.slow
def test_midstream_restore_reproduces_remaining_stream(tmp_path):
    """Checkpoint after phase 2 of a 3-phase stream, restore in a *fresh
    process*, run the remaining phase: metrics must match the uninterrupted
    stream bit-for-bit (the acceptance bar for the lifecycle layer)."""
    stream = build_stream("switch", n_ops_per_app=384, episodes=1,
                          include_baseline=False)
    res = run_stream(stream, CFG, checkpoint_dir=str(tmp_path / "ck"))
    out = tmp_path / "resumed.npz"
    proc = subprocess.run(
        [sys.executable, "-c", _RESUME_SCRIPT, str(tmp_path / "ck"),
         str(out)],
        env=dict(os.environ), capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "RESUME-OK" in proc.stdout
    resumed = np.load(out)
    want = res.phases[2].metrics
    for k in sorted(want):
        np.testing.assert_array_equal(want[k], resumed[k], err_msg=k)


_SHARDED_SCRIPT = textwrap.dedent("""
    import os
    import numpy as np
    import jax
    assert jax.device_count() == 4, jax.devices()

    from repro.nmp import NMPConfig
    from repro.nmp.continual import PolicyStore
    from repro.nmp.engine import default_agent_cfg
    from repro.nmp.scenarios import Scenario, seed_variants
    from repro.nmp.sweep import run_grid
    from repro.nmp.traces import make_trace

    cfg = NMPConfig()
    acfg = default_agent_cfg(cfg)
    tr = make_trace("KM", n_ops=256)

    def phase(name, lineage):
        return seed_variants(Scenario(name=name, trace=tr, mapper="aimm",
                                      lineage=lineage), seeds=(0, 1, 2))

    ckpt = os.environ["CONT_CKPT_DIR"]
    os.environ["REPRO_SWEEP_DEVICES"] = "4"
    r1 = run_grid(phase("p0", "a") + phase("p0b", "b"), cfg)
    assert r1.n_devices == 4
    r1.store.save(ckpt, step=0)

    # restore onto the sharded host and finish; then the same finish on one
    # device must match bit-for-bit
    outs = {}
    for dev in ("4", "1"):
        os.environ["REPRO_SWEEP_DEVICES"] = dev
        store = PolicyStore.restore(ckpt, acfg, step=0)
        outs[dev] = run_grid(phase("p1", "a") + phase("p1b", "b"), cfg,
                             store=store)
    assert (outs["4"].n_devices, outs["1"].n_devices) == (4, 1)
    for k in sorted(outs["1"].metrics):
        np.testing.assert_array_equal(outs["1"].metrics[k],
                                      outs["4"].metrics[k], err_msg=k)
    print("SHARDED-RESTORE-OK")
""")


@pytest.mark.slow
def test_sharded_restore_bit_identical_on_forced_host_devices(tmp_path):
    """A store saved from a sharded (forced 4-device) run restores onto both
    a sharded and a single-device host and finishes the stream identically —
    warm lineage lanes included (3-seed fold + non-divisible lane padding)."""
    env = dict(
        os.environ,
        XLA_FLAGS=("--xla_force_host_platform_device_count=4 "
                   + os.environ.get("XLA_FLAGS", "")),
        JAX_PLATFORMS="cpu",
        CONT_CKPT_DIR=str(tmp_path / "ck"),
    )
    env.pop("REPRO_SWEEP_DEVICES", None)
    proc = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED-RESTORE-OK" in proc.stdout


# ---------------------------------------------------------------------------
# Restore-path edge cases (corruption tolerance) and rollback


def _two_tag_store_dir(tmp_path):
    """Two steps of a two-lineage store: step 0 holds cold_start(0)/(1),
    step 1 holds cold_start(2)/(3)."""
    d = str(tmp_path / "ck")
    store = PolicyStore()
    store.put("a", A.cold_start(0, ACFG))
    store.put("b", A.cold_start(1, ACFG))
    store.save(d, step=0)
    store.put("a", A.cold_start(2, ACFG))
    store.put("b", A.cold_start(3, ACFG))
    store.save(d, step=1)
    return d


def test_restore_falls_back_past_garbage_newest_step(tmp_path):
    """A torn/garbage newest checkpoint (truncated shard) is skipped: the
    store restores from the previous step bit-exactly and reports the
    fallback."""
    from repro.nmp import faults
    d = _two_tag_store_dir(tmp_path)
    shard = os.path.join(d, "step_000000001", "shard_0.npz")
    with open(shard, "r+b") as f:              # truncate: torn write
        f.truncate(os.path.getsize(shard) // 3)
    store = PolicyStore.restore(d, ACFG)
    assert store.restored_step == 0 and store.restore_fallbacks == 1
    assert store.corrupt_tags == []
    assert _leaves_equal(store.get("a"), A.export_agent(A.cold_start(0, ACFG)))
    assert _leaves_equal(store.get("b"), A.export_agent(A.cold_start(1, ACFG)))
    # an explicitly requested garbage step raises instead of falling back
    from repro.train.checkpoint import CheckpointCorruptError
    with pytest.raises(CheckpointCorruptError):
        PolicyStore.restore(d, ACFG, step=1)


def test_restore_empty_dir_clear_error(tmp_path):
    with pytest.raises(FileNotFoundError, match="no checkpoints"):
        PolicyStore.restore(str(tmp_path), ACFG)


def test_restore_corrupted_lineage_cold_starts_only_that_tag(tmp_path):
    """A single lineage whose leaves fail their checksums (silent bit-flip
    that keeps the npz container valid) is dropped — cold-starting on its
    next lookup — while every other lineage restores bit-exactly."""
    from repro.nmp import faults
    from repro.train.checkpoint import CheckpointManager
    d = _two_tag_store_dir(tmp_path)
    meta = CheckpointManager(d).read_meta(1)
    key = next(k for k in meta["leaves"] if k.startswith("a/"))
    faults.tamper_leaf(d, 1, key)
    store = PolicyStore.restore(d, ACFG)
    assert store.restored_step == 1 and store.restore_fallbacks == 0
    assert store.corrupt_tags == ["a"] and "a" not in store
    assert store.meta["a"]["corrupt_restore"] == 1
    assert _leaves_equal(store.get("b"), A.export_agent(A.cold_start(3, ACFG)))


def test_store_rollback_restores_last_good_version(tmp_path):
    """rollback() reverts a lineage to the snapshot its most recent put
    replaced; with no prior version the bad snapshot is dropped so the next
    lookup cold-restarts.  Rollback counts persist through save/restore."""
    store = PolicyStore()
    store.put("t", A.cold_start(0, ACFG))
    v1 = store.get("t")
    store.put("t", A.cold_start(1, ACFG))
    assert store.rollback("t") is True
    assert _leaves_equal(store.get("t"), v1)
    assert store.rollbacks == 1 and store.meta["t"]["rollbacks"] == 1
    # no older version left: rollback drops the lineage entirely
    assert store.rollback("t") is False
    assert "t" not in store
    # counters survive the checkpoint roundtrip
    store.put("t", A.cold_start(2, ACFG))
    d = str(tmp_path / "ck")
    store.save(d, step=0)
    restored = PolicyStore.restore(d, ACFG)
    assert restored.rollbacks == 2
