"""Unit tests: dueling DQN + replay buffer + agent learning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import agent as A
from repro.core import dqn
from repro.core.agent import AgentConfig, init_agent
from repro.core.dqn import DQNConfig
from repro.core.replay import init_replay, push, sample


def test_q_values_shapes():
    cfg = DQNConfig(state_dim=12, n_actions=5)
    params = dqn.init_params(jax.random.PRNGKey(0), cfg)
    q1 = dqn.q_values(params, jnp.zeros(12), cfg)
    qb = dqn.q_values(params, jnp.zeros((7, 12)), cfg)
    assert q1.shape == (5,) and qb.shape == (7, 5)
    assert jnp.isfinite(q1).all()


def test_dueling_identity():
    """Q = V + A - mean(A): mean over actions of (Q - V) must be ~0."""
    cfg = DQNConfig(state_dim=6, n_actions=4)
    params = dqn.init_params(jax.random.PRNGKey(1), cfg)
    s = jax.random.normal(jax.random.PRNGKey(2), (3, 6))
    q = dqn.q_values(params, s, cfg)
    x = jnp.maximum(s @ params["w0"] + params["b0"], 0)
    x = jnp.maximum(x @ params["w1"] + params["b1"], 0)
    v = x @ params["w_v"] + params["b_v"]
    np.testing.assert_allclose(np.asarray(jnp.mean(q - v, axis=-1)), 0.0,
                               atol=1e-5)


def test_replay_ring_semantics():
    buf = init_replay(4, 3)
    for i in range(6):
        buf = push(buf, jnp.full(3, i, jnp.float32), i, float(i),
                   jnp.zeros(3), 0.0)
    assert int(buf.size) == 4
    assert int(buf.ptr) == 2
    # oldest entries overwritten: buffer holds 2..5
    assert set(np.asarray(buf.a).tolist()) == {2, 3, 4, 5}


def test_replay_sample_masks_empty():
    buf = init_replay(8, 3)
    batch = sample(buf, jax.random.PRNGKey(0), 4)
    assert float(batch["w"].sum()) == 0.0
    buf = push(buf, jnp.ones(3), 1, 1.0, jnp.ones(3), 0.0)
    batch = sample(buf, jax.random.PRNGKey(0), 4)
    assert float(batch["w"].sum()) == 4.0


def test_agent_learns_contextual_bandit():
    cfg = AgentConfig(dqn=DQNConfig(state_dim=8, n_actions=8, gamma=0.0))
    ag = init_agent(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)

    def step(carry, _):
        ag, key, s_prev, a_prev, r_prev = carry
        key, k = jax.random.split(key)
        ctx = jax.random.bernoulli(k)
        s = jnp.where(ctx, jnp.ones(8), -jnp.ones(8))
        ag = A.observe(ag, s_prev, a_prev, r_prev, s)
        ag = A.train(ag, cfg)
        a, ag = A.act(ag, cfg, s)
        r = jnp.where(a == jnp.where(ctx, 5, 3), 1.0, -1.0)
        return (ag, key, s, a, r), r

    carry = (ag, key, jnp.zeros(8), jnp.zeros((), jnp.int32), jnp.zeros(()))
    carry, rews = jax.lax.scan(jax.jit(step), carry, None, length=500)
    late = np.asarray(rews)[-100:]
    assert late.mean() > 0.7, late.mean()


def test_q_values_infer_backends_agree():
    """The fused Pallas dueling kernel (interpret mode on CPU) and the plain
    jnp path must agree for both the single-state (act) and batched (TD
    target) shapes the engine uses."""
    cfg = DQNConfig(state_dim=106, n_actions=8)
    params = dqn.init_params(jax.random.PRNGKey(0), cfg)
    for shape in ((106,), (64, 106)):
        s = jax.random.normal(jax.random.PRNGKey(1), shape)
        ref = dqn.q_values_infer(params, s, cfg, backend="jnp")
        pal = dqn.q_values_infer(params, s, cfg, backend="pallas")
        assert pal.shape == ref.shape
        np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(
        np.asarray(dqn.q_values_infer(params, s, cfg, backend="jnp")),
        np.asarray(dqn.q_values(params, s, cfg)))


def test_q_values_infer_falls_back_off_fused_shape():
    """Non-dueling or deeper nets are outside the fused kernel's shape family
    and must silently use the jnp path."""
    cfg = DQNConfig(state_dim=12, n_actions=4, hidden=(32, 32, 32))
    params = dqn.init_params(jax.random.PRNGKey(0), cfg)
    assert not dqn.fused_kernel_compatible(params)
    s = jax.random.normal(jax.random.PRNGKey(1), (5, 12))
    np.testing.assert_array_equal(
        np.asarray(dqn.q_values_infer(params, s, cfg, backend="pallas")),
        np.asarray(dqn.q_values(params, s, cfg)))


def test_qnet_backend_env_var_validated(monkeypatch):
    """An unknown REPRO_QNET_BACKEND must raise a clear error, not silently
    fall back to the jnp path."""
    monkeypatch.setenv("REPRO_QNET_BACKEND", "cuda")
    with pytest.raises(ValueError, match="REPRO_QNET_BACKEND.*cuda"):
        dqn._infer_backend()
    for ok in dqn.QNET_BACKENDS:
        monkeypatch.setenv("REPRO_QNET_BACKEND", ok)
        assert dqn._infer_backend() in ("pallas", "jnp")


def test_qnet_backend_argument_validated():
    cfg = DQNConfig(state_dim=8, n_actions=4)
    params = dqn.init_params(jax.random.PRNGKey(0), cfg)
    s = jnp.zeros((2, 8))
    with pytest.raises(ValueError, match="backend='tpu'"):
        dqn.q_values_infer(params, s, cfg, backend="tpu")
    # explicit "auto" resolves like the env default instead of silently
    # skipping the kernel because it isn't literally "pallas"
    np.testing.assert_array_equal(
        np.asarray(dqn.q_values_infer(params, s, cfg, backend="auto")),
        np.asarray(dqn.q_values_infer(params, s, cfg)))


def test_train_step_noop_until_replay_ready():
    """Pre-`min_replay` the TD step must be an exact no-op (this is what lets
    the engine skip it under lax.cond)."""
    cfg = AgentConfig(dqn=DQNConfig(state_dim=4, n_actions=2), min_replay=8)
    ag = init_agent(jax.random.PRNGKey(0), cfg)
    ag = A.observe(ag, jnp.ones(4), 0, 1.0, jnp.ones(4))
    assert not bool(A.replay_ready(ag, cfg))
    out = A.train_step(ag, cfg, jax.random.PRNGKey(9))
    for a, b in zip(jax.tree.leaves(ag), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_target_sync_periodic():
    cfg = AgentConfig(dqn=DQNConfig(state_dim=4, n_actions=2, target_sync=4),
                      min_replay=1)
    ag = init_agent(jax.random.PRNGKey(0), cfg)
    ag = A.observe(ag, jnp.ones(4), 0, 1.0, jnp.ones(4))
    for i in range(3):
        ag = A.train(ag, cfg)
    # after 3 updates online != target
    d = sum(float(jnp.abs(a - b).sum()) for a, b in
            zip(jax.tree.leaves(ag.params), jax.tree.leaves(ag.target_params)))
    assert d > 0
    ag = A.train(ag, cfg)   # 4th -> sync
    d = sum(float(jnp.abs(a - b).sum()) for a, b in
            zip(jax.tree.leaves(ag.params), jax.tree.leaves(ag.target_params)))
    assert d == 0.0
