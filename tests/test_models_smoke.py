"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU; output shapes and finiteness. Plus prefill<->decode agreement."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model, count_params
from repro.train.optimizer import adamw
from repro.train.train_step import make_train_step


def _batch(cfg, B=2, S=64, key=0):
    rng = np.random.default_rng(key)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if cfg.encoder is not None:
        batch["enc_frames"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)) * 0.1, jnp.bfloat16)
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, cfg.encoder.dec_seq)), jnp.int32)
    if cfg.n_img_tokens:
        batch["img_embed"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_img_tokens, cfg.d_model)) * 0.1,
            jnp.bfloat16)
    batch["labels"] = batch["tokens"]
    return batch


_HEAVY_ARCHS = {"jamba-1.5-large-398b", "gemma3-12b", "llama-3.2-vision-11b",
                "deepseek-moe-16b"}


@pytest.mark.parametrize(
    "arch", [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_ARCHS
             else a for a in ARCHS])
def test_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params, roles = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    hidden, aux = jax.jit(model.apply)(params, batch)
    assert hidden.shape[0] == 2 and hidden.shape[-1] == cfg.d_model
    assert bool(jnp.isfinite(hidden.astype(jnp.float32)).all())

    opt = adamw(1e-3)
    step = jax.jit(make_train_step(model, opt))
    p2, o2, metrics = step(params, opt.init(params), batch,
                           jnp.zeros((), jnp.int32))
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually changed
    d = sum(float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).sum())
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert d > 0


@pytest.mark.parametrize("arch", ["minitron-8b", "mamba2-370m",
                                  "mixtral-8x22b", "gemma3-12b"])
def test_prefill_decode_agreement(arch):
    """Teacher-forced decode must reproduce the full forward's logits at each
    position (KV caches / SSM recurrence vs chunked SSD / ring windows)."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    hidden, _ = model.apply(params, {"tokens": tokens})
    full_logits = model.logits(params, hidden)          # (B, S, V)

    caches = model.init_caches(B, S)
    step = jax.jit(model.decode_step)
    errs = []
    for t in range(S):
        lg, caches = step(params, tokens[:, t:t + 1], caches,
                          jnp.asarray(t, jnp.int32))
        a = np.asarray(full_logits[:, t].astype(jnp.float32))
        b = np.asarray(lg[:, 0].astype(jnp.float32))
        errs.append(np.max(np.abs(a - b)))
    scale = float(np.max(np.abs(np.asarray(
        full_logits.astype(jnp.float32))))) + 1e-6
    assert max(errs) / scale < 0.06, (max(errs), scale)


def test_param_counts_match_nameplates():
    expected = {
        "gemma3-12b": 12e9, "qwen3-32b": 32e9, "jamba-1.5-large-398b": 398e9,
        "mixtral-8x22b": 141e9, "deepseek-moe-16b": 16e9, "mamba2-370m": .37e9,
    }
    for arch, n in expected.items():
        got = count_params(get_config(arch))
        assert abs(got - n) / n < 0.12, (arch, got, n)


def test_moe_active_params_smaller():
    cfg = get_config("mixtral-8x22b")
    assert count_params(cfg, active_only=True) < 0.5 * count_params(cfg)
