"""Optimizer / loss / grad-accumulation / compression correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.models import build_model
from repro.train.compression import compress_decompress, \
    topk_with_error_feedback
from repro.train.optimizer import (_dq8, _q8, adamw, cosine_schedule,
                                   quantizable, quantized_adamw, sgd)
from repro.train.train_step import chunked_ce_loss, make_loss_fn, \
    make_train_step


def _quad_problem():
    target = jnp.asarray(np.random.default_rng(0).standard_normal((4, 256)),
                         jnp.float32)
    params = {"w": jnp.zeros((4, 256))}
    grad_fn = jax.grad(lambda p: jnp.mean((p["w"] - target) ** 2))
    return params, grad_fn, target


def test_adamw_converges_quadratic():
    params, grad_fn, target = _quad_problem()
    opt = adamw(0.05)
    state = opt.init(params)
    for i in range(200):
        params, state = opt.update(grad_fn(params), state, params,
                                   jnp.asarray(i))
    assert float(jnp.mean((params["w"] - target) ** 2)) < 1e-2


def test_quantized_adamw_tracks_adamw():
    params, grad_fn, target = _quad_problem()
    opt_a, opt_q = adamw(0.05), quantized_adamw(0.05)
    pa, pq = params, params
    sa, sq = opt_a.init(params), opt_q.init(params)
    for i in range(100):
        pa, sa = opt_a.update(grad_fn(pa), sa, pa, jnp.asarray(i))
        pq, sq = opt_q.update(grad_fn(pq), sq, pq, jnp.asarray(i))
    # both converge to similar loss despite int8 moments
    la = float(jnp.mean((pa["w"] - target) ** 2))
    lq = float(jnp.mean((pq["w"] - target) ** 2))
    assert lq < max(3 * la, 5e-2), (la, lq)


def test_quantized_fallback_for_odd_leaves():
    params = {"a": jnp.zeros((3, 7)), "b": jnp.zeros((2, 256))}
    opt = quantized_adamw(0.1)
    state = opt.init(params)
    assert "m" in state["a"] and "mq" in state["b"]
    g = jax.tree.map(jnp.ones_like, params)
    p2, s2 = opt.update(g, state, params, jnp.asarray(0))
    assert jnp.isfinite(p2["a"]).all() and jnp.isfinite(p2["b"]).all()


@settings(deadline=None, max_examples=25)
@given(st.integers(1, 4), st.integers(1, 8))
def test_q8_roundtrip_error_bound(rows, blocks):
    x = np.random.default_rng(rows * 100 + blocks).standard_normal(
        (rows, blocks * 256)).astype(np.float32) * 10
    q, s = _q8(jnp.asarray(x))
    back = np.asarray(_dq8(q, s, x.shape))
    blockmax = np.abs(x.reshape(rows, blocks, 256)).max(-1, keepdims=True)
    bound = (blockmax / 127.0 * 0.5 + 1e-6).repeat(256, -1).reshape(x.shape)
    assert (np.abs(back - x) <= bound + 1e-5).all()


def test_chunked_ce_matches_dense():
    cfg = get_config("minitron-8b", smoke=True)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    B, S = 2, 64
    tokens = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab,
                                                           (B, S)), jnp.int32)
    hidden, _ = model.apply(params, {"tokens": tokens})
    loss_chunked = chunked_ce_loss(model, params, hidden, tokens, z_loss=0.0)
    logits = model.logits(params, hidden).astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, tokens[..., None], axis=-1)[..., 0]
    loss_dense = jnp.mean(lse - tgt)
    np.testing.assert_allclose(float(loss_chunked), float(loss_dense),
                               rtol=1e-4)


def test_grad_accumulation_equivalence():
    """microbatches=4 must reproduce the full-batch gradient step."""
    cfg = get_config("minitron-8b", smoke=True)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    B, S = 8, 32
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    opt = sgd(1e-2)
    s1 = jax.jit(make_train_step(model, opt, microbatches=1))
    s4 = jax.jit(make_train_step(model, opt, microbatches=4))
    p1, _, m1 = s1(params, {}, batch, jnp.asarray(0))
    p4, _, m4 = s4(params, {}, batch, jnp.asarray(0))
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=2e-2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0.1, atol=2e-3)


def test_compression_int8_small_error():
    g = jnp.asarray(np.random.default_rng(0).standard_normal(4096),
                    jnp.float32)
    out = compress_decompress(g)
    rel = float(jnp.abs(out - g).max() / jnp.abs(g).max())
    assert rel < 0.01


def test_topk_error_feedback_conserves():
    g = jnp.asarray(np.random.default_rng(1).standard_normal(1024),
                    jnp.float32)
    sent, resid = topk_with_error_feedback(g, jnp.zeros_like(g), frac=0.05)
    np.testing.assert_allclose(np.asarray(sent + resid), np.asarray(g),
                               atol=1e-6)
    assert float((sent != 0).mean()) <= 0.06


def test_cosine_schedule():
    sched = cosine_schedule(1.0, warmup=10, total=100)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert abs(float(sched(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(sched(jnp.asarray(100))) <= 0.11
