"""Streaming multi-tenant mapping service (nmp.serving).

Pins the serving layer's contract: per-tenant phase results bit-identical to
running the tenant's stream alone via `continual.run_stream`; resident
compiled programs that never recompile at steady state as tenants churn;
slot recycling under arrival/departure; duplicate lineage tags rejected; and
a capacity-bounded PolicyStore serving more tenants than its capacity —
surviving lineages bit-exact, evicted ones cold-restarting transparently.
"""
import numpy as np
import pytest

from repro.nmp import NMPConfig, partition, sweep
from repro.nmp.continual import PolicyStore, run_stream
from repro.nmp.scenarios import Scenario, tenant_fleet, tenant_stream
from repro.nmp.serving import MappingServer, solo_stream
from repro.nmp.traces import make_trace

CFG = NMPConfig()
N_OPS = 384
# n_slots rounds up to the device-mesh width, so slot-count-sensitive
# assertions must use the effective count (the forced-4-device CI lane runs
# this file with every slot program sharded over a 4-wide lane mesh)
SLOTS2 = partition.padded_lane_count(2, partition.build_mesh())


def _fleet(n_tenants, n_phases=2, apps=("KM", "SC")):
    return tenant_fleet(n_tenants=n_tenants, apps=apps, n_phases=n_phases,
                        n_ops_per_app=N_OPS)


def _submit_all(srv, fleet):
    for tid, stream in fleet.items():
        srv.submit(tid, stream)


def _assert_tenant_matches_solo(srv, tid, stream, cfg=CFG):
    solo = run_stream(solo_stream(tid, stream), cfg)
    for pi in range(len(stream)):
        served = srv.tenant_metrics(tid, pi)
        want = solo.phases[pi].metrics
        for k in sorted(want):
            np.testing.assert_array_equal(served[k], want[k][0],
                                          err_msg=f"{tid} phase{pi} {k}")


def test_serving_bit_identical_to_solo_run_stream():
    """Every tenant's per-phase metric arrays — served through shared slot
    programs, mixed with other tenants, warm-started via the store — must
    equal the tenant's solo run_stream bit-for-bit (the acceptance bar)."""
    fleet = _fleet(3)
    srv = MappingServer(CFG, n_slots=2)
    _submit_all(srv, fleet)
    srv.run()
    assert all(srv.tenant(t).done for t in fleet)
    for tid, stream in fleet.items():
        _assert_tenant_matches_solo(srv, tid, stream)


def test_zero_recompiles_at_steady_state():
    """After the first tick compiles the resident slot program, further
    ticks — tenant churn included — must not add compiled programs."""
    fleet = _fleet(4, n_phases=2)
    srv = MappingServer(CFG, n_slots=2)
    _submit_all(srv, fleet)
    served = srv.tick()
    assert served == min(4, SLOTS2)
    n_prog = sweep.compiled_sweep_programs()
    while srv.tick():
        pass
    assert sweep.compiled_sweep_programs() == n_prog
    st = srv.stats()
    assert st["recompiles_after_first_tick"] == 0
    assert st["phases_served"] == 8 and st["tenants_done"] == 4


def test_tenant_churn_arrive_depart_mid_stream():
    """Tenants arriving mid-service get recycled slots; a removed tenant
    frees its slot without serving its remaining phases, and the remaining
    tenants' results stay bit-identical to their solo runs."""
    fleet = _fleet(2, n_phases=3)
    srv = MappingServer(CFG, n_slots=2)
    _submit_all(srv, fleet)
    assert srv.tick() == 2
    # depart t000 mid-stream; its slot must be recycled to the new arrival
    srv.remove("t000")
    late = tenant_stream(apps=("KM",), n_phases=1, n_ops_per_app=N_OPS,
                         seed=9)
    srv.submit("late", late)
    srv.run()
    t0, t1 = srv.tenant("t000"), srv.tenant("t001")
    assert t0.removed and t0.done and len(t0.results) == 1
    assert t1.done and len(t1.results) == 3
    assert srv.tenant("late").done
    _assert_tenant_matches_solo(srv, "t001", fleet["t001"])
    _assert_tenant_matches_solo(srv, "late", late)
    # removing a queued (never-scheduled) tenant works too
    srv2 = MappingServer(CFG, n_slots=1)
    _submit_all(srv2, _fleet(2, n_phases=1))
    srv2.remove("t001")           # still queued: slot 0 holds t000
    srv2.run()
    assert srv2.tenant("t001").removed
    assert len(srv2.tenant("t001").results) == 0


def test_duplicate_tenant_ids_rejected_while_live():
    fleet = _fleet(1)
    srv = MappingServer(CFG, n_slots=2)
    srv.submit("dup", fleet["t000"])
    with pytest.raises(ValueError, match="already live"):
        srv.submit("dup", fleet["t000"])
    srv.run()
    # a drained id may be reused (its lineage continues in the store)
    srv.submit("dup", fleet["t000"])
    srv.run()
    assert srv.stats()["phases_served"] == 4


def test_store_eviction_under_capacity_pressure():
    """More tenants than store capacity: the server keeps serving, reports
    evictions, and tenants that were never evicted mid-stream stay
    bit-exact vs an unbounded-store run of the same fleet."""
    fleet = _fleet(6, n_phases=2)
    cap = SLOTS2 + 1                         # >= slots (warm actives), < 6
    bounded = MappingServer(CFG, n_slots=2, store_capacity=cap)
    _submit_all(bounded, fleet)
    bounded.run()
    st = bounded.stats()
    assert st["store"]["evictions"] > 0
    assert len(bounded.store) <= cap
    assert st["tenants_done"] == 6
    # slots hold a tenant to completion and capacity >= n_slots, so active
    # lineages are always most-recent => never evicted mid-stream: every
    # tenant must match its solo (= unbounded) run bit-exactly
    for tid, stream in fleet.items():
        _assert_tenant_matches_solo(bounded, tid, stream)


def test_evicted_lineage_cold_restarts_transparently():
    """capacity=1 with two interleaving tenants: each put evicts the other
    tag, so every phase after the first cold-restarts its lineage — without
    error, and bit-identical to a per-phase cold (fresh-lineage) run."""
    tr = make_trace("KM", n_ops=N_OPS)
    phases = [Scenario(name=f"p{i}:KM/aimm", trace=tr, mapper="aimm",
                       seed=s) for i, s in ((0, 0), (1, 1))]
    srv = MappingServer(CFG, n_slots=2, store_capacity=1)
    srv.submit("a", [[p] for p in phases])
    srv.submit("b", [[p] for p in phases])
    srv.run()
    assert srv.store.evictions > 0 and len(srv.store) == 1
    # puts land in slot order (a then b) each tick, so with capacity=1 the
    # store holds only "b" between ticks: "a" was evicted before its phase-1
    # warm lookup and must equal a cold run of that phase alone, while "b"
    # survived and must equal its warm solo run
    from repro.nmp.sweep import run_grid
    import dataclasses
    cold = run_grid([dataclasses.replace(phases[1], lineage="fresh")], CFG)
    got = srv.tenant_metrics("a", 1)
    for k in ("cycles", "ops", "opc_t", "invoke_t"):
        np.testing.assert_array_equal(got[k], cold.metrics[k][0],
                                      err_msg=f"evicted a {k}")
    _assert_tenant_matches_solo(srv, "b", [[p] for p in phases])


def test_submit_validation():
    tr = make_trace("KM", n_ops=N_OPS)
    srv = MappingServer(CFG, n_slots=2)
    with pytest.raises(ValueError, match="lineage tag"):
        srv.submit("a/b", [[Scenario(name="x", trace=tr, mapper="aimm")]])
    with pytest.raises(ValueError, match="empty stream"):
        srv.submit("a", [])
    with pytest.raises(ValueError, match="learned-AIMM"):
        srv.submit("a", [[Scenario(name="x", trace=tr, mapper="none")]])
    with pytest.raises(ValueError, match="single-lane"):
        srv.submit("a", [[Scenario(name="x", trace=tr, mapper="aimm")] * 2])
    srv.submit("a", [[Scenario(name="x", trace=tr, mapper="aimm",
                               episodes=2)]])
    with pytest.raises(ValueError, match="episode count"):
        srv.submit("b", [[Scenario(name="x", trace=tr, mapper="aimm",
                                   episodes=1)]])
    with pytest.raises(ValueError, match="topology"):
        srv.submit("c", [[Scenario(name="x", trace=tr, mapper="aimm",
                                   episodes=2, topology="ring")]])


def test_frozen_envelope_rejects_oversized_latecomer():
    """Once the envelope freezes at the first tick, a tenant whose trace
    exceeds it is rejected at submit (clear error, no recompile)."""
    srv = MappingServer(CFG, n_slots=2)
    srv.submit("small", tenant_stream(apps=("KM",), n_phases=1,
                                      n_ops_per_app=N_OPS))
    srv.tick()
    with pytest.raises(ValueError, match="frozen"):
        srv.submit("big", tenant_stream(apps=("KM",), n_phases=1,
                                        n_ops_per_app=4 * N_OPS))


def test_forced_envelope_and_slot_rounding():
    """An explicit envelope admits anything it dominates from tick one, and
    n_slots rounds up to the device-mesh width (1 on a single device)."""
    from repro.nmp.plan import plan_envelope
    big = tenant_stream(apps=("KM", "SC"), n_phases=2,
                        n_ops_per_app=2 * N_OPS)
    env = plan_envelope([sc for ph in big for sc in ph], CFG)
    srv = MappingServer(CFG, n_slots=3, envelope=env)
    srv.submit("small", tenant_stream(apps=("KM",), n_phases=1,
                                      n_ops_per_app=N_OPS))
    srv.submit("big", big)
    srv.run()
    assert srv.tenant("small").done and srv.tenant("big").done
    _assert_tenant_matches_solo(srv, "big", big)


def test_remove_while_phase_in_flight_drops_prepared_entry():
    """Removing a tenant whose next phase already sits in the prepared
    (double-buffered) batch must drop that entry on advance: the lane still
    executes, but the result is discarded and the lineage is not written
    back — nothing after the removal is observable — while co-tenants stay
    bit-identical to their solo runs."""
    fleet = _fleet(2, n_phases=3)
    srv = MappingServer(CFG, n_slots=2)
    _submit_all(srv, fleet)
    srv.run(max_ticks=1)            # phase 0 served, phase 1 batch prepared
    assert srv._pending is not None
    v0 = srv.store.version("t000")
    srv.remove("t000")              # its phase-1 entry is now in flight
    assert srv._pending is not None  # prepared batch survives the removal
    srv.run()
    t0 = srv.tenant("t000")
    assert t0.removed and len(t0.results) == 1       # phase 0 only
    assert srv.store.version("t000") == v0           # no post-removal put
    assert srv.stats()["faults"]["stale_dropped"] >= 1
    assert srv.tenant("t001").done
    _assert_tenant_matches_solo(srv, "t001", fleet["t001"])


def test_tenant_fleet_builder_shares_traces():
    fleet = _fleet(4, n_phases=2)
    assert len(fleet) == 4
    traces = {id(sc.trace) for s in fleet.values() for ph in s for sc in ph}
    assert len(traces) <= 2          # one Trace per (app, n_ops)
    seeds = {sc.seed for s in fleet.values() for ph in s for sc in ph}
    assert len(seeds) == 4           # heterogeneous tenants
