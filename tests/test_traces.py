"""Trace generators reproduce the paper's §6.5 workload characteristics."""
import numpy as np
import pytest

from repro.nmp.traces import APPS, analyze, make_trace, merge_traces, \
    program_of_page


@pytest.mark.parametrize("app", APPS)
def test_trace_wellformed(app):
    tr = make_trace(app, n_ops=2048)
    for arr in (tr.dest, tr.src1, tr.src2):
        assert arr.shape == (2048,)
        assert arr.min() >= 0 and arr.max() < tr.n_pages
    assert tr.read_write[np.unique(tr.dest)].all()   # dest pages are RW


def test_determinism():
    a = make_trace("PR", n_ops=1024)
    b = make_trace("PR", n_ops=1024)
    assert (a.dest == b.dest).all() and (a.src1 == b.src1).all()


def test_active_page_classes():
    """Fig. 5b: {LUD, PR, RBM, SC} have high active-page fractions (working
    set ~ residency); {BP, SPMV} low — reproduce the relative ordering."""
    frac = {}
    for app in APPS:
        tr = make_trace(app, n_ops=4096)
        frac[app] = analyze(tr)["active_pages_mean"] / tr.n_pages
    high = min(frac[a] for a in ("LUD", "RBM", "SC"))
    low = max(frac[a] for a in ("BP", "SPMV"))
    assert high > low, frac


def test_affinity_radix_ordering():
    """Fig. 5c: graph-like kernels (PR, LUD, RBM) have higher radix than
    streaming kernels (MAC, RD)."""
    rad = {app: analyze(make_trace(app, n_ops=4096))["radix_mean"]
           for app in APPS}
    assert min(rad["PR"], rad["RBM"]) > max(rad["MAC"], rad["RD"]), rad


def test_bp_large_residency_small_ws():
    """BP: huge page count, small working set (paper §7.3)."""
    a = analyze(make_trace("BP", n_ops=4096))
    tr = make_trace("BP", n_ops=4096)
    assert tr.n_pages >= 2048
    assert a["active_pages_mean"] < tr.n_pages * 0.2


def test_merge_traces_multiprogram():
    t1 = make_trace("KM", n_ops=512)
    t2 = make_trace("RD", n_ops=512)
    m = merge_traces([t1, t2])
    assert m.n_ops == 1024
    assert m.n_pages == t1.n_pages + t2.n_pages
    # page spaces disjoint per program
    owner = program_of_page(m)
    p0 = np.unique(np.concatenate([m.dest[m.program_id == 0],
                                   m.src1[m.program_id == 0]]))
    assert (owner[p0] == 0).all()
    assert m.iter_ops > 0


def _stream_of(m, pid):
    """Ops of program `pid` in merge order, shifted back to its page space."""
    sel = m.program_id == pid
    return m.dest[sel], m.src1[sel], m.src2[sel]


def test_merge_traces_non_divisible_interleave_remainder():
    """Op counts that don't divide the interleave burst: the trailing partial
    bursts must still land, every op exactly once, stream order preserved."""
    t1 = make_trace("KM", n_ops=100)      # 100 = 3*32 + 4
    t2 = make_trace("RD", n_ops=50)       # 50 = 32 + 18
    m = merge_traces([t1, t2], interleave=32)
    assert m.n_ops == 150
    assert np.bincount(m.program_id, minlength=2).tolist() == [100, 50]
    off = t1.n_pages
    for pid, t, o in ((0, t1, 0), (1, t2, off)):
        d, s1, s2 = _stream_of(m, pid)
        np.testing.assert_array_equal(d - o, t.dest)     # order preserved
        np.testing.assert_array_equal(s1 - o, t.src1)
        np.testing.assert_array_equal(s2 - o, t.src2)


def test_merge_traces_single_app_combo():
    """A one-program 'combo' is the identity modulo nothing: same ops, same
    pages, all program ids zero."""
    t = make_trace("SPMV", n_ops=300)
    m = merge_traces([t], interleave=32)
    assert m.n_ops == t.n_ops and m.n_pages == t.n_pages
    np.testing.assert_array_equal(m.dest, t.dest)
    np.testing.assert_array_equal(m.src1, t.src1)
    np.testing.assert_array_equal(m.src2, t.src2)
    assert (m.program_id == 0).all()
    np.testing.assert_array_equal(m.read_write, t.read_write)


def test_merge_traces_empty_tail_after_short_program_exhausts():
    """Very unequal lengths: once the short program drains, the tail must be
    purely the long program's remaining ops (no zero-filled filler ops), and
    RW flags must carry over per page space."""
    t1 = make_trace("KM", n_ops=512)
    t2 = make_trace("RD", n_ops=64)       # drains after 2 bursts
    m = merge_traces([t1, t2], interleave=32)
    assert m.n_ops == 576
    # tail beyond the last t2 op is all program 0
    last_p1 = np.max(np.nonzero(m.program_id == 1)[0])
    assert (m.program_id[last_p1 + 1:] == 0).all()
    assert m.program_id[last_p1 + 1:].size == 512 - (last_p1 + 1 - 64)
    d, s1, s2 = _stream_of(m, 0)
    np.testing.assert_array_equal(d, t1.dest)            # nothing dropped
    off = t1.n_pages
    np.testing.assert_array_equal(m.read_write[:off], t1.read_write)
    np.testing.assert_array_equal(m.read_write[off:], t2.read_write)
