"""Trace generators reproduce the paper's §6.5 workload characteristics."""
import numpy as np
import pytest

from repro.nmp.traces import APPS, analyze, make_trace, merge_traces, \
    program_of_page


@pytest.mark.parametrize("app", APPS)
def test_trace_wellformed(app):
    tr = make_trace(app, n_ops=2048)
    for arr in (tr.dest, tr.src1, tr.src2):
        assert arr.shape == (2048,)
        assert arr.min() >= 0 and arr.max() < tr.n_pages
    assert tr.read_write[np.unique(tr.dest)].all()   # dest pages are RW


def test_determinism():
    a = make_trace("PR", n_ops=1024)
    b = make_trace("PR", n_ops=1024)
    assert (a.dest == b.dest).all() and (a.src1 == b.src1).all()


def test_active_page_classes():
    """Fig. 5b: {LUD, PR, RBM, SC} have high active-page fractions (working
    set ~ residency); {BP, SPMV} low — reproduce the relative ordering."""
    frac = {}
    for app in APPS:
        tr = make_trace(app, n_ops=4096)
        frac[app] = analyze(tr)["active_pages_mean"] / tr.n_pages
    high = min(frac[a] for a in ("LUD", "RBM", "SC"))
    low = max(frac[a] for a in ("BP", "SPMV"))
    assert high > low, frac


def test_affinity_radix_ordering():
    """Fig. 5c: graph-like kernels (PR, LUD, RBM) have higher radix than
    streaming kernels (MAC, RD)."""
    rad = {app: analyze(make_trace(app, n_ops=4096))["radix_mean"]
           for app in APPS}
    assert min(rad["PR"], rad["RBM"]) > max(rad["MAC"], rad["RD"]), rad


def test_bp_large_residency_small_ws():
    """BP: huge page count, small working set (paper §7.3)."""
    a = analyze(make_trace("BP", n_ops=4096))
    tr = make_trace("BP", n_ops=4096)
    assert tr.n_pages >= 2048
    assert a["active_pages_mean"] < tr.n_pages * 0.2


def test_merge_traces_multiprogram():
    t1 = make_trace("KM", n_ops=512)
    t2 = make_trace("RD", n_ops=512)
    m = merge_traces([t1, t2])
    assert m.n_ops == 1024
    assert m.n_pages == t1.n_pages + t2.n_pages
    # page spaces disjoint per program
    owner = program_of_page(m)
    p0 = np.unique(np.concatenate([m.dest[m.program_id == 0],
                                   m.src1[m.program_id == 0]]))
    assert (owner[p0] == 0).all()
    assert m.iter_ops > 0
