import os
# Tests run on the single real CPU device; the 512-device override is ONLY for
# the dry-run (repro.launch.dryrun sets it before importing jax).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
