import functools
import inspect
import os
import random
import sys
import types

import pytest

# Tests run on the single real CPU device; the 512-device override is ONLY for
# the dry-run (repro.launch.dryrun sets it before importing jax).
os.environ.setdefault("JAX_PLATFORMS", "cpu")


# ---------------------------------------------------------------------------
# Optional-hypothesis shim
# ---------------------------------------------------------------------------
# The property tests use a small hypothesis subset (given / settings /
# strategies.{integers,sampled_from,lists,tuples}). When the real package is
# available (requirements-dev.txt) it is used unchanged; otherwise a minimal
# deterministic fallback is installed so the tier-1 suite still collects and
# exercises every property test on a fixed sample of draws.

_FALLBACK_EXAMPLES = int(os.environ.get("HYP_FALLBACK_EXAMPLES", "4"))


def _install_hypothesis_fallback():
    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda r: seq[r.randrange(len(seq))])

    def lists(elements, min_size=0, max_size=10):
        return _Strategy(lambda r: [elements.draw(r) for _ in
                                    range(r.randint(min_size, max_size))])

    def tuples(*elements):
        return _Strategy(lambda r: tuple(e.draw(r) for e in elements))

    def settings(**kw):
        def deco(fn):
            fn._hyp_settings = dict(kw)
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_hyp_settings", {}).get(
                    "max_examples", _FALLBACK_EXAMPLES)
                n = max(1, min(n, _FALLBACK_EXAMPLES))
                rng = random.Random(0)
                seen = set()
                for _ in range(n):
                    drawn = tuple(s.draw(rng) for s in strats)
                    key = repr(drawn)
                    if key in seen:        # dedupe repeated draws
                        continue
                    seen.add(key)
                    fn(*args, *drawn, **kwargs)
            # pytest must not treat the generated arguments as fixtures
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco

    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.integers, st.sampled_from = integers, sampled_from
    st.lists, st.tuples = lists, tuples
    mod.given, mod.settings, mod.strategies = given, settings, st
    mod.__is_fallback__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _install_hypothesis_fallback()


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-episode AIMM / large-trace tests (deselect with "
        "-m 'not slow')")


# ---------------------------------------------------------------------------
# Shared fixtures: small traces, built once per session
# ---------------------------------------------------------------------------

@pytest.fixture(scope="session")
def nmp_cfg():
    from repro.nmp import NMPConfig
    return NMPConfig()


@pytest.fixture(scope="session")
def spmv_trace():
    """Default small trace for engine tests (shared so jit caches are reused)."""
    from repro.nmp.traces import make_trace
    return make_trace("SPMV", n_ops=1024)


@pytest.fixture(scope="session")
def km_trace():
    from repro.nmp.traces import make_trace
    return make_trace("KM", n_ops=512)
