"""Roofline HLO parser: shape-byte parsing, trip-count correction, dot FLOPs
validated against a known lowered program."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.roofline import (Roofline, _shape_bytes, parse_hlo_costs)


def test_shape_bytes():
    assert _shape_bytes("f32[2,3]{1,0}") == 24
    assert _shape_bytes("bf16[128]") == 256
    assert _shape_bytes("(s32[], f32[4,4]{1,0}, bf16[2]{0})") == 4 + 64 + 4
    assert _shape_bytes("pred[7]") == 7


def test_parser_trip_correction_scanned_matmul():
    """A scanned matmul chain: parsed dot FLOPs must equal trips * per-dot."""
    L, M, K = 12, 64, 64

    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    x = jnp.ones((M, K))
    w = jnp.ones((L, K, K))
    hlo = jax.jit(f).lower(x, w).compile().as_text()
    stats = parse_hlo_costs(hlo)
    expect = L * 2 * M * K * K
    assert stats.flops == pytest.approx(expect, rel=0.01), (
        stats.flops, expect, stats.trip_counts)
    assert any(t == L for t in stats.trip_counts.values())


def test_parser_handles_nested_tuple_shapes():
    """Nested scans with tuple carries produce nested-tuple HLO shapes; the
    parser must still find the whiles and multiply nested trip counts."""
    M = 64      # large enough that XLA keeps a real `dot` op

    def f(x):
        def outer(c, _):
            def inner(d, _):
                return (d[0] + 1.0, jnp.tanh(d[1] @ d[1])), None
            d, _ = jax.lax.scan(inner, c, None, length=3)
            return d, None
        c, _ = jax.lax.scan(outer, (x, x), None, length=5)
        return c[0].sum() + c[1].sum()

    x = jnp.ones((M, M))
    hlo = jax.jit(f).lower(x).compile().as_text()
    stats = parse_hlo_costs(hlo)
    expect = 5 * 3 * 2 * M ** 3
    assert stats.flops == pytest.approx(expect, rel=0.05), (
        stats.flops, stats.trip_counts)


def test_roofline_terms_and_dominance():
    r = Roofline(flops=197e12 * 256, bytes_hbm=0.1, bytes_collective=0.1,
                 chips=256, model_flops=197e12 * 256)
    assert r.compute_s == pytest.approx(1.0)
    assert r.dominant == "compute"
    assert r.roofline_fraction == pytest.approx(1.0)
    r2 = Roofline(flops=1, bytes_hbm=819e9 * 512, bytes_collective=1,
                  chips=256, model_flops=1)
    assert r2.dominant == "memory"
    assert r2.memory_s == pytest.approx(2.0)


def test_memory_model_sanity():
    from repro.configs import SHAPES, get_config
    from repro.launch.memory_model import memory_bytes
    cfg = get_config("minitron-8b")
    train = memory_bytes(cfg, SHAPES["train_4k"], mb=8)
    decode = memory_bytes(cfg, SHAPES["decode_32k"])
    prefill = memory_bytes(cfg, SHAPES["prefill_32k"])
    assert train > prefill > 0
    assert decode > 2 * 2 * cfg.param_count()   # reads weights + caches
    # more microbatches -> more weight re-reads
    assert memory_bytes(cfg, SHAPES["train_4k"], mb=16) > train
