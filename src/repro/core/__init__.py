"""AIMM core: the paper's primary contribution.

A continual-learning (dueling double-DQN) agent that remaps data pages and
NMP computation in a memory-cube network (repro.nmp is the environment), plus
the beyond-paper retargeting of the same agent at TPU-mesh sharding decisions
(repro.core.sharding_mapper).
"""
from repro.core import actions, dqn, replay, reward, state  # noqa: F401
from repro.core.agent import AgentConfig, AgentState, init_agent  # noqa: F401
from repro.core.dqn import DQNConfig  # noqa: F401
