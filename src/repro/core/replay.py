"""Experience replay buffer (paper §4.3 / §5.2 "replay buffer").

A fixed-capacity ring buffer of (s, a, r, s2, done) transitions held in plain
jnp arrays, so it can be carried through `jax.lax.scan` and updated with pure
functional ops. Sampling is uniform with a validity mask for the not-yet-full
case (the TD loss masks invalid rows).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ReplayBuffer(NamedTuple):
    s: jnp.ndarray        # (cap, state_dim) f32
    a: jnp.ndarray        # (cap,) i32
    r: jnp.ndarray        # (cap,) f32
    s2: jnp.ndarray       # (cap, state_dim) f32
    done: jnp.ndarray     # (cap,) f32
    ptr: jnp.ndarray      # () i32
    size: jnp.ndarray     # () i32


def init_replay(capacity: int, state_dim: int) -> ReplayBuffer:
    return ReplayBuffer(
        s=jnp.zeros((capacity, state_dim), jnp.float32),
        a=jnp.zeros((capacity,), jnp.int32),
        r=jnp.zeros((capacity,), jnp.float32),
        s2=jnp.zeros((capacity, state_dim), jnp.float32),
        done=jnp.zeros((capacity,), jnp.float32),
        ptr=jnp.zeros((), jnp.int32),
        size=jnp.zeros((), jnp.int32),
    )


def push(buf: ReplayBuffer, s, a, r, s2, done) -> ReplayBuffer:
    cap = buf.s.shape[0]
    i = buf.ptr
    s, a, r = (jnp.asarray(x) for x in (s, a, r))
    return ReplayBuffer(
        s=buf.s.at[i].set(s.astype(jnp.float32)),
        a=buf.a.at[i].set(a.astype(jnp.int32)),
        r=buf.r.at[i].set(r.astype(jnp.float32)),
        s2=buf.s2.at[i].set(s2.astype(jnp.float32)),
        done=buf.done.at[i].set(jnp.asarray(done, jnp.float32)),
        ptr=(i + 1) % cap,
        size=jnp.minimum(buf.size + 1, cap),
    )


def sample(buf: ReplayBuffer, rng: jax.Array, batch_size: int) -> dict:
    """Uniform sample with validity weights; safe when buffer is near-empty."""
    hi = jnp.maximum(buf.size, 1)
    idx = jax.random.randint(rng, (batch_size,), 0, hi)
    # Every drawn index is < size, so all rows are valid as soon as the buffer
    # is non-empty; an empty buffer masks the whole batch.
    w = jnp.where(buf.size > 0, jnp.ones((batch_size,), jnp.float32),
                  jnp.zeros((batch_size,), jnp.float32))
    return {
        "s": buf.s[idx],
        "a": buf.a[idx],
        "r": buf.r[idx],
        "s2": buf.s2[idx],
        "done": buf.done[idx],
        "w": w,
    }
