"""AIMM state representation (paper §4.2, Fig. 3).

State = [ system information | page information ]:

  system: per-cube NMP-table occupancy, per-cube avg row-buffer hit rate,
          per-MC queue occupancy, global action history, interval level.
  page:   (for the selected highly-accessed page) page access rate,
          migrations-per-access, hop-count history, round-trip latency history,
          migration latency history, per-page action history, current host
          cube and current compute cube (one-hot).

All features are normalized to O(1) ranges so a single MLP scale works across
mesh sizes (4x4 and 8x8) and workloads.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.actions import N_ACTIONS, N_INTERVALS


@dataclasses.dataclass(frozen=True)
class StateSpec:
    n_cubes: int
    n_mcs: int
    hop_hist: int = 8
    lat_hist: int = 8
    mig_hist: int = 4
    act_hist: int = 4       # per-page action history length
    global_act_hist: int = 8

    @property
    def dim(self) -> int:
        return (
            self.n_cubes            # NMP table occupancy per cube
            + self.n_cubes          # row-buffer hit rate per cube
            + self.n_mcs            # MC queue occupancy
            + self.global_act_hist  # global action history (normalized ids)
            + N_INTERVALS           # interval level one-hot
            + 2                     # page access rate, migrations per access
            + self.hop_hist
            + self.lat_hist
            + self.mig_hist
            + self.act_hist
            + self.n_cubes          # page host cube one-hot
            + self.n_cubes          # page compute cube one-hot
        )


def build_state(
    spec: StateSpec,
    nmp_occ: jnp.ndarray,        # (n_cubes,) in [0, inf) ops
    rb_hit: jnp.ndarray,         # (n_cubes,) in [0, 1]
    mc_queue: jnp.ndarray,       # (n_mcs,) ops
    global_actions: jnp.ndarray, # (global_act_hist,) int action ids
    interval_level: jnp.ndarray, # () int
    page_access_rate: jnp.ndarray,
    page_mig_per_access: jnp.ndarray,
    page_hop_hist: jnp.ndarray,  # (hop_hist,) hops
    page_lat_hist: jnp.ndarray,  # (lat_hist,) cycles
    page_mig_hist: jnp.ndarray,  # (mig_hist,) cycles
    page_act_hist: jnp.ndarray,  # (act_hist,) int action ids
    page_cube: jnp.ndarray,      # () int host cube
    compute_cube: jnp.ndarray,   # () int compute cube
    *,
    occ_norm: float = 512.0,     # NMP table capacity
    queue_norm: float = 64.0,
    hop_norm: float = 8.0,
    lat_norm: float = 500.0,
) -> jnp.ndarray:
    one_hot = lambda i, n: (jnp.arange(n) == i).astype(jnp.float32)
    parts = [
        jnp.clip(nmp_occ / occ_norm, 0, 2),
        rb_hit,
        jnp.clip(mc_queue / queue_norm, 0, 2),
        global_actions.astype(jnp.float32) / N_ACTIONS,
        one_hot(interval_level, N_INTERVALS),
        jnp.stack([jnp.clip(page_access_rate, 0, 1),
                   jnp.clip(page_mig_per_access, 0, 2)]),
        jnp.clip(page_hop_hist / hop_norm, 0, 2),
        jnp.clip(page_lat_hist / lat_norm, 0, 4),
        jnp.clip(page_mig_hist / lat_norm, 0, 4),
        page_act_hist.astype(jnp.float32) / N_ACTIONS,
        one_hot(page_cube, spec.n_cubes),
        one_hot(compute_cube, spec.n_cubes),
    ]
    s = jnp.concatenate([jnp.atleast_1d(p).reshape(-1) for p in parts])
    assert s.shape[0] == spec.dim, (s.shape, spec.dim)
    return s
