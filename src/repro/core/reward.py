"""AIMM reward function (paper §4.2).

The paper explored hop count as the metric but found it converges to a local
minimum; operations-per-cycle (OPC) as a direct performance proxy gives a
robust learning signal. Reward is +1 / -1 / 0 for improvement / degradation /
no-change, with a small relative deadband so measurement noise does not
produce spurious +-1 rewards.
"""
from __future__ import annotations

import jax.numpy as jnp

DEADBAND = 1e-3  # relative OPC change treated as "no change"


def compute_reward(opc_now: jnp.ndarray, opc_prev: jnp.ndarray,
                   deadband: float = DEADBAND) -> jnp.ndarray:
    rel = (opc_now - opc_prev) / jnp.maximum(opc_prev, 1e-9)
    return jnp.where(rel > deadband, 1.0, jnp.where(rel < -deadband, -1.0, 0.0))
