"""Dueling (double) deep Q-network in pure JAX (paper §4.3, Fig. 4-3).

The agent's function approximator is a small stack of fully connected layers
with a dueling head:  Q(s, a) = V(s) + A(s, a) - mean_a A(s, a).

Everything here is a pure function over explicit parameter pytrees so the
whole continual-learning loop (simulate -> act -> observe -> train) can live
inside a single `jax.lax.scan`.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class DQNConfig:
    state_dim: int
    n_actions: int = 8
    hidden: tuple[int, ...] = (128, 128)
    dueling: bool = True
    double: bool = True           # double-DQN target (beyond-paper robustness)
    gamma: float = 0.95
    lr: float = 1e-3
    grad_clip: float = 1.0
    target_sync: int = 64         # train steps between target-network syncs
    batch_size: int = 64


def init_params(rng: jax.Array, cfg: DQNConfig) -> PyTree:
    dims = (cfg.state_dim,) + cfg.hidden
    keys = jax.random.split(rng, len(dims) + 2)
    params = {}
    for i in range(len(dims) - 1):
        scale = jnp.sqrt(2.0 / dims[i])
        params[f"w{i}"] = jax.random.normal(keys[i], (dims[i], dims[i + 1]), jnp.float32) * scale
        params[f"b{i}"] = jnp.zeros((dims[i + 1],), jnp.float32)
    h = dims[-1]
    if cfg.dueling:
        params["w_v"] = jax.random.normal(keys[-2], (h, 1), jnp.float32) * jnp.sqrt(1.0 / h)
        params["b_v"] = jnp.zeros((1,), jnp.float32)
        params["w_a"] = jax.random.normal(keys[-1], (h, cfg.n_actions), jnp.float32) * jnp.sqrt(1.0 / h)
        params["b_a"] = jnp.zeros((cfg.n_actions,), jnp.float32)
    else:
        params["w_q"] = jax.random.normal(keys[-1], (h, cfg.n_actions), jnp.float32) * jnp.sqrt(1.0 / h)
        params["b_q"] = jnp.zeros((cfg.n_actions,), jnp.float32)
    return params


def zeros_params(cfg: DQNConfig) -> PyTree:
    """Zero-filled parameter pytree with `init_params`' exact structure,
    shapes and dtypes, built without an RNG.  This is the restore template
    for checkpointed agents: a fresh process can rebuild the tree skeleton
    and map saved leaves onto it without replaying the init key."""
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


def q_values(params: PyTree, state: jnp.ndarray, cfg: DQNConfig) -> jnp.ndarray:
    """Q(s, .) for a single state (state_dim,) or batch (B, state_dim)."""
    squeeze = state.ndim == 1
    x = jnp.atleast_2d(state.astype(jnp.float32))
    i = 0
    while f"w{i}" in params:
        x = jnp.maximum(x @ params[f"w{i}"] + params[f"b{i}"], 0.0)
        i += 1
    if cfg.dueling:
        v = x @ params["w_v"] + params["b_v"]                     # (B, 1)
        a = x @ params["w_a"] + params["b_a"]                     # (B, A)
        q = v + a - jnp.mean(a, axis=-1, keepdims=True)
    else:
        q = x @ params["w_q"] + params["b_q"]
    return q[0] if squeeze else q


QNET_BACKENDS = ("auto", "pallas", "jnp")


def _validate_backend(mode: str, source: str) -> str:
    if mode not in QNET_BACKENDS:
        raise ValueError(
            f"{source}={mode!r} is not a valid qnet backend; expected one of "
            f"{QNET_BACKENDS}. 'auto' picks the fused Pallas kernel on TPU "
            "and jnp elsewhere; 'pallas' forces the kernel (interpret mode "
            "off-TPU); 'jnp' forces the plain XLA path.")
    return mode


def _resolve_auto(mode: str) -> str:
    """The `auto` policy: the fused Pallas kernel on TPU, plain jnp elsewhere
    (single definition shared by the env-var default and explicit args)."""
    if mode == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return mode


def _infer_backend() -> str:
    """Backend for gradient-free Q inference.

    `REPRO_QNET_BACKEND` ∈ {auto, pallas, jnp}: `auto` picks the fused Pallas
    kernel on TPU (the paper's §5.2 RL-accelerator analogue) and plain jnp
    elsewhere; `pallas` forces the kernel (interpret mode off-TPU — used by
    the wiring tests, slow on CPU).  Unknown values raise (validated here and
    eagerly at import below) rather than silently falling back to jnp.  Read
    at trace time: flipping the env var does not invalidate already-jitted
    programs.
    """
    return _resolve_auto(_validate_backend(
        os.environ.get("REPRO_QNET_BACKEND", "auto"), "REPRO_QNET_BACKEND"))


# Fail fast on a typo'd override: a bad REPRO_QNET_BACKEND should abort at
# import, not silently run the wrong backend deep inside a jitted sweep.
_validate_backend(os.environ.get("REPRO_QNET_BACKEND", "auto"),
                  "REPRO_QNET_BACKEND")


def fused_kernel_compatible(params: PyTree) -> bool:
    """The fused Pallas kernel covers the production shape: dueling head over
    exactly two hidden layers."""
    return "w_v" in params and "w1" in params and "w2" not in params


def q_values_infer(params: PyTree, state: jnp.ndarray, cfg: DQNConfig,
                   backend: str | None = None) -> jnp.ndarray:
    """Q(s, .) for inference-only consumers (action selection, TD targets).

    Numerically equivalent to `q_values` but free to route through the fused
    Pallas dueling-qnet kernel (one launch for the whole batch, weights
    resident in VMEM) since no gradient flows through it.
    """
    backend = (_infer_backend() if backend is None
               else _resolve_auto(_validate_backend(backend, "backend")))
    if backend == "pallas" and fused_kernel_compatible(params):
        from repro.kernels.dueling_qnet.ops import qnet_forward
        squeeze = state.ndim == 1
        x = jnp.atleast_2d(state.astype(jnp.float32))
        q = qnet_forward(params, x)
        return q[0] if squeeze else q
    return q_values(params, state, cfg)


def td_loss(params: PyTree, target_params: PyTree, batch: dict, cfg: DQNConfig) -> jnp.ndarray:
    """Squared TD error (paper eq. 3), double-DQN target if cfg.double.

    Only the Q(s, a) term carries gradients; the target-network values and the
    double-DQN argmax selection are inference (stop_gradient) and go through
    `q_values_infer`, i.e. the fused Pallas kernel where available.
    """
    q = q_values(params, batch["s"], cfg)                          # (B, A)
    q_sa = jnp.take_along_axis(q, batch["a"][:, None], axis=1)[:, 0]
    q_next_t = jax.lax.stop_gradient(
        q_values_infer(target_params, batch["s2"], cfg))           # (B, A)
    if cfg.double:
        q_next_o = jax.lax.stop_gradient(
            q_values_infer(params, batch["s2"], cfg))
        a_star = jnp.argmax(q_next_o, axis=-1)
        q_next = jnp.take_along_axis(q_next_t, a_star[:, None], axis=1)[:, 0]
    else:
        q_next = jnp.max(q_next_t, axis=-1)
    y = batch["r"] + cfg.gamma * (1.0 - batch["done"]) * q_next
    err = (y - q_sa) * batch["w"]          # `w` masks invalid (not-yet-filled) samples
    return jnp.sum(jnp.square(err)) / jnp.maximum(jnp.sum(batch["w"]), 1.0)


def num_params(cfg: DQNConfig) -> int:
    n, prev = 0, cfg.state_dim
    for h in cfg.hidden:
        n += prev * h + h
        prev = h
    n += prev * 1 + 1 + prev * cfg.n_actions + cfg.n_actions
    return n
