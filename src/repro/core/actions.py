"""AIMM action space (paper §4.2).

Eight actions: six data/computation remaps plus two agent-invocation-interval
adjustments. Remap targets are expressed relative to the hot page's *compute*
cube in the 2D cube array (paper wording), with "near" = random neighbour and
"far" = diagonally opposite cube.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Action ids (paper order).
DEFAULT = 0            # (i)   no mapping change
NEAR_DATA = 1          # (ii)  migrate page to a random neighbour of the compute cube
FAR_DATA = 2           # (iii) migrate page to the diagonally opposite cube
NEAR_COMPUTE = 3       # (iv)  remap compute to a random neighbour cube
FAR_COMPUTE = 4        # (v)   remap compute to the diagonally opposite cube
SOURCE_COMPUTE = 5     # (vi)  remap compute to the host cube of the first source page
INC_INTERVAL = 6       # (vii) increase agent invocation interval
DEC_INTERVAL = 7       # (viii)decrease agent invocation interval

N_ACTIONS = 8

# Discrete invocation intervals, in cycles (paper §4.2). The engine translates
# these into per-epoch op-window sizes.
INTERVALS = (100, 125, 167, 250)
N_INTERVALS = len(INTERVALS)

ACTION_NAMES = (
    "default", "near_data", "far_data", "near_compute", "far_compute",
    "source_compute", "inc_interval", "dec_interval",
)


def cube_xy(cube: jnp.ndarray, mesh_x: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    return cube % mesh_x, cube // mesh_x


def xy_cube(x: jnp.ndarray, y: jnp.ndarray, mesh_x: int) -> jnp.ndarray:
    return y * mesh_x + x


def random_neighbor(rng: jax.Array, cube: jnp.ndarray, mesh_x: int, mesh_y: int) -> jnp.ndarray:
    """Uniformly pick one of the (up to 4) mesh neighbours of `cube`.

    Off-mesh candidates are replaced by the cube itself before sampling, then
    invalid picks fall back to a valid direction, so the result is always a
    legal cube id.
    """
    x, y = cube_xy(cube, mesh_x)
    cand_x = jnp.stack([x - 1, x + 1, x, x])
    cand_y = jnp.stack([y, y, y - 1, y + 1])
    valid = (cand_x >= 0) & (cand_x < mesh_x) & (cand_y >= 0) & (cand_y < mesh_y)
    # Sample a direction proportional to validity.
    p = valid.astype(jnp.float32)
    p = p / jnp.maximum(p.sum(), 1.0)
    d = jax.random.choice(rng, 4, p=p)
    nx = jnp.clip(cand_x[d], 0, mesh_x - 1)
    ny = jnp.clip(cand_y[d], 0, mesh_y - 1)
    return xy_cube(nx, ny, mesh_x)


def diagonal_opposite(cube: jnp.ndarray, mesh_x: int, mesh_y: int) -> jnp.ndarray:
    x, y = cube_xy(cube, mesh_x)
    return xy_cube(mesh_x - 1 - x, mesh_y - 1 - y, mesh_x)


def adjust_interval(level: jnp.ndarray, action: jnp.ndarray) -> jnp.ndarray:
    """Apply INC/DEC interval actions to the discrete interval level."""
    delta = jnp.where(action == INC_INTERVAL, 1, jnp.where(action == DEC_INTERVAL, -1, 0))
    return jnp.clip(level + delta, 0, N_INTERVALS - 1)
