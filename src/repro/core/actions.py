"""AIMM action space (paper §4.2).

Eight actions: six data/computation remaps plus two agent-invocation-interval
adjustments. Remap targets are expressed relative to the hot page's *compute*
cube, with "near" = random neighbour and "far" = the topology's far table
(the diagonally opposite cube on the paper's 2D mesh; the hop-farthest cube
on other interconnects).  The target tables are precomputed per topology
(`repro.nmp.topology.Topology.nbr`/`far`), so the action machinery is
topology-agnostic gathers + one categorical draw.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Action ids (paper order).
DEFAULT = 0            # (i)   no mapping change
NEAR_DATA = 1          # (ii)  migrate page to a random neighbour of the compute cube
FAR_DATA = 2           # (iii) migrate page to the diagonally opposite cube
NEAR_COMPUTE = 3       # (iv)  remap compute to a random neighbour cube
FAR_COMPUTE = 4        # (v)   remap compute to the diagonally opposite cube
SOURCE_COMPUTE = 5     # (vi)  remap compute to the host cube of the first source page
INC_INTERVAL = 6       # (vii) increase agent invocation interval
DEC_INTERVAL = 7       # (viii)decrease agent invocation interval

N_ACTIONS = 8

# Discrete invocation intervals, in cycles (paper §4.2). The engine translates
# these into per-epoch op-window sizes.
INTERVALS = (100, 125, 167, 250)
N_INTERVALS = len(INTERVALS)

ACTION_NAMES = (
    "default", "near_data", "far_data", "near_compute", "far_compute",
    "source_compute", "inc_interval", "dec_interval",
)


def random_neighbor(rng: jax.Array, cube: jnp.ndarray, nbr: jnp.ndarray,
                    nbr_valid: jnp.ndarray) -> jnp.ndarray:
    """Uniformly pick one of `cube`'s topology neighbours.

    `nbr`/`nbr_valid` are the topology's (C, D) neighbour table and validity
    mask (invalid slots hold the cube itself).  Sampling is a categorical
    draw over the D slots proportional to validity, so an invalid slot is
    never picked and the result is always a legal cube id.  On the 2D mesh
    the table keeps the historical candidate slot order [x-1, x+1, y-1, y+1]
    and D = 4, so the draw is bit-identical to the historical coordinate
    arithmetic."""
    cand = nbr[cube]                                 # (D,)
    valid = nbr_valid[cube]
    p = valid.astype(jnp.float32)
    p = p / jnp.maximum(p.sum(), 1.0)
    d = jax.random.choice(rng, cand.shape[0], p=p)
    return cand[d]


def far_target(cube: jnp.ndarray, far: jnp.ndarray) -> jnp.ndarray:
    """The topology's "far" remap target for `cube` (precomputed table: the
    mirror-diagonal cube on the 2D mesh, the hop-farthest cube elsewhere)."""
    return far[cube]


def adjust_interval(level: jnp.ndarray, action: jnp.ndarray) -> jnp.ndarray:
    """Apply INC/DEC interval actions to the discrete interval level."""
    delta = jnp.where(action == INC_INTERVAL, 1, jnp.where(action == DEC_INTERVAL, -1, 0))
    return jnp.clip(level + delta, 0, N_INTERVALS - 1)
