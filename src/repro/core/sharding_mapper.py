"""AIMM retargeted at the TPU pod (beyond-paper integration).

The paper's core idea — a continual dueling-DQN plugin that remaps data and
computation, rewarded by system throughput — applied to the mapping problem a
TPU training framework actually has. The environment is the analytic roofline
cost model over the real knob space the dry-run exposes:

  state   : workload descriptors (params, tokens, arithmetic intensity) +
            current knob settings + the three roofline terms (normalized) —
            the Fig.-3 analogue (system info + "page" info = mapping info)
  actions : (i) keep mapping, (ii/iii) microbatch up/down, (iv/v) remat
            up/down, (vi) toggle FSDP param sharding, (vii) toggle int8
            optimizer moments, (viii) toggle MoE expert parallelism
  reward  : +-1 on estimated-step-time improvement, with an HBM-capacity
            barrier (a mapping that doesn't fit is an immediate -1)

The same repro.core agent (dueling double-DQN + replay) drives it, exactly as
the NMP plugin, and `search()` is the production entry point: it returns the
best mapping found plus the visited trajectory for EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeCfg
from repro.core import agent as agent_mod
from repro.core.agent import AgentConfig
from repro.core.dqn import DQNConfig
from repro.launch.memory_model import memory_bytes
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.models.model import count_params, model_flops

HBM_PER_CHIP = 16e9          # v5e
MB_LADDER = (1, 2, 4, 8, 16, 32)
REMAT_LADDER = ("none", "block", "full")
REMAT_FLOPS = {"none": 1.0, "block": 1.15, "full": 4.0 / 3.0}
# activation-residency fractions calibrated against dry-run memory_analysis
# (§Perf C1: the original guesses made remat='none' look free at 398B)
REMAT_ACT_MEM = {"none": 1.0, "block": 0.3, "full": 0.12}
ACT_IO_PASSES = 16.0        # tensors/layer kept live without remat (measured)


@dataclasses.dataclass(frozen=True)
class Knobs:
    microbatches: int = 8
    remat: str = "full"
    fsdp: bool = False
    quant_opt: bool = False
    moe_ep: bool = True


class CostModel:
    """Analytic step-time estimate for (cfg, shape, mesh_shape)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeCfg,
                 mesh_shape=(16, 16)):
        self.cfg = cfg
        self.shape = shape
        self.chips = int(np.prod(mesh_shape))
        self.model_par = mesh_shape[-1]
        self.data_par = self.chips // self.model_par
        self.N = count_params(cfg)
        self.Na = count_params(cfg, active_only=True)
        self.mf = model_flops(cfg, shape)

    def hbm_per_chip(self, k: Knobs) -> float:
        param_shards = self.model_par * (self.data_par if k.fsdp else 1)
        params = 2.0 * self.N / param_shards
        grads = 4.0 * self.N / self.chips           # ZeRO-sharded fp32
        opt = (2.0 if k.quant_opt else 8.0) * self.N / self.chips
        T = self.shape.global_batch * self.shape.seq
        act = (REMAT_ACT_MEM[k.remat] * T * self.cfg.d_model * 2.0
               * self.cfg.n_layers / max(k.microbatches, 1) / self.chips
               * ACT_IO_PASSES)
        return params + grads + opt + act

    def compute_s(self, k: Knobs) -> float:
        return (self.mf * REMAT_FLOPS[k.remat]) / (self.chips * PEAK_FLOPS)

    def memory_s(self, k: Knobs) -> float:
        b = memory_bytes(self.cfg, self.shape, mb=k.microbatches,
                         quantized_opt=k.quant_opt)
        return b / (self.chips * HBM_BW)

    def collective_s(self, k: Knobs) -> float:
        T = self.shape.global_batch * self.shape.seq
        D = self.cfg.d_model
        L = self.cfg.n_layers
        # Megatron TP: ~4 all-reduces of the hidden per layer per microbatch
        # pass (fwd+bwd), traffic ~ 2x payload
        tp = 4.0 * L * T * D * 2.0 * 2.0 * 2.0
        # DP gradient reduce-scatter+all-gather ~ 2 x params (bf16 wire)
        dp = 4.0 * self.N
        # FSDP param all-gather per microbatch (fwd+bwd)
        fsdp = (2.0 * self.N * 2.0 * k.microbatches) if k.fsdp else 0.0
        # MoE: EP moves ~2 x token payload x top_k per MoE layer; TP-in-expert
        # with capacity dispatch moves the whole (E, C, D) dispatch buffer
        # through the mesh every pass (measured pathological, §Perf A4/C1)
        moe = 0.0
        if self.cfg.moe is not None:
            n_moe = self.cfg.n_super * sum(
                1 for _, f in self.cfg.pattern if f == "E")
            kk = self.cfg.moe.top_k
            if k.moe_ep:
                moe = n_moe * T * D * 2.0 * kk * 2.0
            else:
                # measured (A4/C1): GSPMD replicates the f32 dispatch buffers
                # across the data axis instead of exchanging payloads
                cf = self.cfg.moe.capacity_factor
                moe = (n_moe * T * kk * cf * D * 4.0 * 3.0
                       * max(self.data_par, 1))
        return (tp + dp + fsdp + moe) / (self.chips * ICI_BW)

    def step_s(self, k: Knobs) -> float:
        if self.hbm_per_chip(k) > HBM_PER_CHIP:
            return float("inf")
        return max(self.compute_s(k), self.memory_s(k), self.collective_s(k))

    def objective(self, k: Knobs) -> float:
        """Finite shaped objective: infeasible mappings are scored by how far
        over HBM they are, so the agent gets a gradient toward feasibility
        (a bare `inf` gives no learning signal on the OOM plateau)."""
        t = max(self.compute_s(k), self.memory_s(k), self.collective_s(k))
        over = self.hbm_per_chip(k) / HBM_PER_CHIP
        if over > 1.0:
            return 1e3 * over
        return t


# ---------------------------------------------------------------------------
# RL search over the knob space (the AIMM loop, environment = cost model)
# ---------------------------------------------------------------------------

N_ACTIONS = 8
STATE_DIM = 24


def _state_vec(cm: CostModel, k: Knobs) -> jnp.ndarray:
    c, m, co = cm.compute_s(k), cm.memory_s(k), cm.collective_s(k)
    tot = max(c + m + co, 1e-12)
    hbm = cm.hbm_per_chip(k) / HBM_PER_CHIP
    feats = [
        np.log10(max(cm.N, 1)) / 12.0,
        np.log10(max(cm.mf, 1)) / 20.0,
        cm.Na / max(cm.N, 1),
        MB_LADDER.index(k.microbatches) / len(MB_LADDER),
        REMAT_LADDER.index(k.remat) / len(REMAT_LADDER),
        float(k.fsdp), float(k.quant_opt), float(k.moe_ep),
        min(c / tot, 1.0), min(m / tot, 1.0), min(co / tot, 1.0),
        min(hbm, 4.0) / 4.0,
        float(cm.cfg.moe is not None),
        float(cm.shape.kind == "train"),
        cm.shape.seq / 1e6, cm.shape.global_batch / 512.0,
    ]
    feats += [0.0] * (STATE_DIM - len(feats))
    return jnp.asarray(feats, jnp.float32)


def _apply_action(k: Knobs, a: int) -> Knobs:
    if a == 1:
        i = MB_LADDER.index(k.microbatches)
        return dataclasses.replace(k, microbatches=MB_LADDER[
            min(i + 1, len(MB_LADDER) - 1)])
    if a == 2:
        i = MB_LADDER.index(k.microbatches)
        return dataclasses.replace(k, microbatches=MB_LADDER[max(i - 1, 0)])
    if a == 3:
        i = REMAT_LADDER.index(k.remat)
        return dataclasses.replace(k, remat=REMAT_LADDER[
            min(i + 1, len(REMAT_LADDER) - 1)])
    if a == 4:
        i = REMAT_LADDER.index(k.remat)
        return dataclasses.replace(k, remat=REMAT_LADDER[max(i - 1, 0)])
    if a == 5:
        return dataclasses.replace(k, fsdp=not k.fsdp)
    if a == 6:
        return dataclasses.replace(k, quant_opt=not k.quant_opt)
    if a == 7:
        return dataclasses.replace(k, moe_ep=not k.moe_ep)
    return k


class SearchResult(NamedTuple):
    best: Knobs
    best_step_s: float
    baseline_step_s: float
    trajectory: list


def search(cfg: ModelConfig, shape: ShapeCfg, mesh_shape=(16, 16),
           steps: int = 300, seed: int = 0,
           start: Knobs = Knobs()) -> SearchResult:
    """Continual-learning mapping search; returns best mapping + trajectory."""
    cm = CostModel(cfg, shape, mesh_shape)
    acfg = AgentConfig(dqn=DQNConfig(state_dim=STATE_DIM, n_actions=N_ACTIONS,
                                     gamma=0.0), eps_start=0.5, eps_decay=80,
                       min_replay=16)
    ag = agent_mod.init_agent(jax.random.PRNGKey(seed), acfg)

    k = start
    baseline = cm.step_s(k)
    best, best_t = k, baseline
    prev_s, prev_a = _state_vec(cm, k), jnp.asarray(0)
    prev_t = cm.objective(k)
    traj = [(k, baseline)]
    for i in range(steps):
        s = _state_vec(cm, k)
        t = cm.objective(k)
        if cm.step_s(k) < best_t:
            best, best_t = k, cm.step_s(k)
        r = 0.0 if i == 0 else (1.0 if t < prev_t * 0.999 else
                                (-1.0 if t > prev_t * 1.001 else 0.0))
        ag = agent_mod.observe(ag, prev_s, prev_a, jnp.asarray(r), s)
        ag = agent_mod.train(ag, acfg)
        a, ag = agent_mod.act(ag, acfg, s)
        prev_s, prev_a, prev_t = s, a, t
        k = _apply_action(k, int(a))
        traj.append((k, cm.step_s(k)))
    return SearchResult(best, best_t, baseline, traj)


def exhaustive_best(cfg: ModelConfig, shape: ShapeCfg,
                    mesh_shape=(16, 16)) -> tuple[Knobs, float]:
    """Ground-truth optimum over the knob lattice (small enough to sweep)."""
    cm = CostModel(cfg, shape, mesh_shape)
    best, best_t = None, float("inf")
    for mb, rm, fs, qo, ep in itertools.product(
            MB_LADDER, REMAT_LADDER, (False, True), (False, True),
            (False, True)):
        k = Knobs(mb, rm, fs, qo, ep)
        t = cm.step_s(k)
        if t < best_t:
            best, best_t = k, t
    return best, best_t
