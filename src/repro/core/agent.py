"""Continual ε-greedy Q-learning agent (paper §4.3, §5.2).

The agent is a NamedTuple of arrays (scan-compatible). Per invocation:

  act      : ε-greedy action from the online dueling network
  observe  : append (s, a, r, s') to the replay ring buffer
  train    : one minibatch TD step (Adam), with periodic target-network sync

"Continual learning" per the paper: the DNN persists across episode resets —
only the environment state is cleared between runs (see nmp.engine.run_program).
The engine invokes the whole observe -> train -> act pipeline only on
invocation epochs (under `jax.lax.cond`); epochs between invocations carry
the agent through untouched.  Gradient-free inference (act, TD targets) can
route through the fused Pallas dueling-qnet kernel (see core.dqn.q_values_infer).

Lifecycle API (the continual layer, nmp.continual, builds on these):

  cold_start     : the canonical fresh-agent convention (PRNGKey(seed + 1))
  hand_off       : scenario-boundary handoff — per-scenario counters reset,
                   lifetime state (DNN, replay, global_step) carries over
  export_agent / import_agent : host-side numpy snapshot <-> AgentState
  agent_template : RNG-free AgentState skeleton (checkpoint restore target)

`AgentState.global_step` counts env interactions over the agent's whole
lifetime and is never reset by `hand_off`; the ε-greedy schedule keys on it,
so exploration decays across scenario/program switches instead of restarting
at every boundary.  For a cold-started agent `global_step == step` until the
first handoff, so single-scenario behavior is unchanged.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dqn
from repro.core.dqn import DQNConfig
from repro.core.replay import ReplayBuffer, init_replay, push, sample
from repro.train.optimizer import adamw

PyTree = Any


class AgentState(NamedTuple):
    params: PyTree
    target_params: PyTree
    opt_state: PyTree
    replay: ReplayBuffer
    step: jnp.ndarray          # env interactions in the current scenario
    train_steps: jnp.ndarray   # gradient updates taken (lifetime)
    rng: jax.Array
    loss_ema: jnp.ndarray
    global_step: jnp.ndarray   # lifetime env interactions (never reset)


class AgentConfig(NamedTuple):
    dqn: DQNConfig
    replay_capacity: int = 4096
    eps_start: float = 0.3
    eps_end: float = 0.02
    eps_decay: int = 120       # interactions to decay over
    train_every: int = 1       # train each invocation (continual)
    min_replay: int = 32


def init_agent(rng: jax.Array, cfg: AgentConfig) -> AgentState:
    k1, k2 = jax.random.split(rng)
    params = dqn.init_params(k1, cfg.dqn)
    opt = adamw(cfg.dqn.lr, grad_clip=cfg.dqn.grad_clip)
    return AgentState(
        params=params,
        target_params=jax.tree.map(jnp.copy, params),
        opt_state=opt.init(params),
        replay=init_replay(cfg.replay_capacity, cfg.dqn.state_dim),
        step=jnp.zeros((), jnp.int32),
        train_steps=jnp.zeros((), jnp.int32),
        rng=k2,
        loss_ema=jnp.zeros(()),
        global_step=jnp.zeros((), jnp.int32),
    )


def cold_start(seed, cfg: AgentConfig) -> AgentState:
    """The engine's fresh-agent convention: one agent per scenario seed,
    keyed off PRNGKey(seed + 1).  `seed` may be a traced scalar (the sweep
    cold-starts whole lanes inside jit)."""
    return init_agent(jax.random.PRNGKey(seed + 1), cfg)


def hand_off(agent: AgentState) -> AgentState:
    """Scenario-boundary handoff (program switch, co-runner churn): the agent
    continues its lifetime — DNN weights, target net, Adam moments, replay
    buffer, RNG stream and `global_step` all carry over — while the
    per-scenario interaction counter resets.  ε-greedy exploration keys on
    `global_step`, so it keeps decaying across the boundary."""
    return agent._replace(step=jnp.zeros((), jnp.int32))


def export_agent(agent: AgentState) -> AgentState:
    """Host-side numpy snapshot of an agent (same pytree structure).  The
    snapshot is detached from any device/mesh, so it can be stored, compared
    or checkpointed regardless of where the agent ran."""
    return jax.tree.map(lambda a: np.asarray(jax.device_get(a)), agent)


def import_agent(snapshot: AgentState) -> AgentState:
    """Re-materialize an exported snapshot as device arrays (dtypes kept)."""
    return jax.tree.map(jnp.asarray, snapshot)


def agent_template(cfg: AgentConfig) -> AgentState:
    """RNG-free AgentState skeleton: every leaf has the shape/dtype of a real
    agent but zero contents (params via `dqn.zeros_params`).  Checkpoint
    restore targets are built from this, so a fresh process can restore an
    agent without replaying the init RNG."""
    params = dqn.zeros_params(cfg.dqn)
    opt = adamw(cfg.dqn.lr, grad_clip=cfg.dqn.grad_clip)
    return AgentState(
        params=params,
        target_params=jax.tree.map(jnp.copy, params),
        opt_state=opt.init(params),
        replay=init_replay(cfg.replay_capacity, cfg.dqn.state_dim),
        step=jnp.zeros((), jnp.int32),
        train_steps=jnp.zeros((), jnp.int32),
        rng=jax.random.PRNGKey(0),
        loss_ema=jnp.zeros(()),
        global_step=jnp.zeros((), jnp.int32),
    )


def epsilon(cfg: AgentConfig, step: jnp.ndarray) -> jnp.ndarray:
    frac = jnp.exp(-step.astype(jnp.float32) / cfg.eps_decay)
    return cfg.eps_end + (cfg.eps_start - cfg.eps_end) * frac


def act(agent: AgentState, cfg: AgentConfig, state_vec: jnp.ndarray,
        explore: bool | jnp.ndarray = True) -> tuple[jnp.ndarray, AgentState]:
    """ε-greedy action selection; returns (action, new agent state).

    `explore` may be a traced boolean (batched sweeps flip exploration per
    episode inside one compiled program); RNG consumption is identical either
    way, so greedy evaluation stays reproducible against static calls.
    """
    rng, k_eps, k_act = jax.random.split(agent.rng, 3)
    q = dqn.q_values_infer(agent.params, state_vec, cfg.dqn)
    greedy = jnp.argmax(q).astype(jnp.int32)
    # ε decays over the agent's *lifetime* (global_step survives scenario
    # handoffs); for a cold-started agent global_step == step, so cold
    # first-episode behavior matches the historical per-scenario schedule.
    eps = epsilon(cfg, agent.global_step)
    rand_a = jax.random.randint(k_act, (), 0, cfg.dqn.n_actions)
    take_rand = jnp.asarray(explore) & (jax.random.uniform(k_eps) < eps)
    action = jnp.where(take_rand, rand_a, greedy)
    return action, agent._replace(rng=rng, step=agent.step + 1,
                                  global_step=agent.global_step + 1)


def observe(agent: AgentState, s, a, r, s2, done=0.0) -> AgentState:
    return agent._replace(replay=push(agent.replay, s, a, r, s2, done))


def replay_ready(agent: AgentState, cfg: AgentConfig) -> jnp.ndarray:
    """True once the replay buffer holds enough samples for a real TD step.

    Monotone in time; while False, `train_step` is an exact no-op (masked
    batch, zero grads onto zero Adam moments, no step count), which is what
    lets the engine skip the whole minibatch under `lax.cond` until some lane
    is ready.
    """
    return agent.replay.size >= cfg.min_replay


def train(agent: AgentState, cfg: AgentConfig) -> AgentState:
    """One TD minibatch step; no-op (via masking) until replay has min_replay."""
    rng, k = jax.random.split(agent.rng)
    return train_step(agent._replace(rng=rng), cfg, k)


def train_step(agent: AgentState, cfg: AgentConfig,
               rng: jax.Array) -> AgentState:
    """`train` with the minibatch RNG drawn by the caller (`agent.rng` is not
    consumed here, so the engine can advance the stream unconditionally and
    gate the expensive TD step itself behind `lax.cond`)."""
    opt = adamw(cfg.dqn.lr, grad_clip=cfg.dqn.grad_clip)
    batch = sample(agent.replay, rng, cfg.dqn.batch_size)
    ready = (agent.replay.size >= cfg.min_replay).astype(jnp.float32)
    batch = dict(batch, w=batch["w"] * ready)

    loss, grads = jax.value_and_grad(dqn.td_loss)(
        agent.params, agent.target_params, batch, cfg.dqn)
    # Zero the update entirely when not ready (grads of masked loss are 0 anyway,
    # but Adam moments should not accumulate noise).
    grads = jax.tree.map(lambda g: g * ready, grads)
    new_params, new_opt = opt.update(grads, agent.opt_state, agent.params,
                                     agent.train_steps)
    train_steps = agent.train_steps + jnp.asarray(ready, jnp.int32)

    # Periodic hard target sync.
    sync = (train_steps % cfg.dqn.target_sync == 0) & (train_steps > 0)
    new_target = jax.tree.map(
        lambda t, p: jnp.where(sync, p, t), agent.target_params, new_params)

    return agent._replace(
        params=new_params,
        target_params=new_target,
        opt_state=new_opt,
        train_steps=train_steps,
        loss_ema=0.99 * agent.loss_ema + 0.01 * loss,
    )


def step_agent(agent: AgentState, cfg: AgentConfig, prev_s, prev_a, reward,
               new_s) -> tuple[jnp.ndarray, AgentState]:
    """Full continual-learning invocation: observe -> train -> act.

    This is the hardware flow of Fig. 4-2: the incoming (state, reward) pair
    plus the buffered (prev state, prev action) form a replay sample; the agent
    then infers the next action for the new state.
    """
    agent = observe(agent, prev_s, prev_a, reward, new_s)
    agent = train(agent, cfg)
    return act(agent, cfg, new_s)
