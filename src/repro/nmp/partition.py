"""Partition layer of the sweep pipeline: device meshes + lane/seed sharding.

Builds a 2-D `jax.sharding.Mesh` over the available devices with axes
`("lanes", "seeds")` and places a group batch (see
`nmp.plan.build_group_batch`) on it: per-lane arrays are sharded along the
lane axis (`NamedSharding(P("lanes"))`), the episode seed schedule — the one
input with a folded seed axis — along both (`P("lanes", "seeds")`), and
everything lane-independent is replicated.  The execute layer's jitted
program then runs SPMD across the mesh: per-(lane, seed) work never crosses
a device, the only collectives are the scalar "any lane invokes / profiles"
reductions that feed the engine's `lax.cond` gates, so sharded per-cell
metrics are bit-identical to the single-device run for EVERY mesh shape.

Mesh shape: by default the execute layer auto-factors the visible device
count into (lane, seed) dims that minimize padded-cell waste for the plan at
hand (`auto_mesh_shape`); `REPRO_SWEEP_MESH=LxS` forces a shape.  A shape of
`(n, 1)` is exactly the historical 1-D lane mesh.

Lane counts are padded up to a lane-dim-divisible size by repeating the
first lane, and group seed axes up to a seed-dim-divisible width by
repeating seed slot 0 (padding lanes/slots are real, legal simulations whose
outputs the execute layer never reads).

Degrades gracefully: with a single device (plain CPU CI) `build_mesh`
returns None and the execute layer skips placement entirely.  Multi-device
CPU testing is forced with `XLA_FLAGS=--xla_force_host_platform_device_count=N`
(set before importing jax).

Multi-host scaffolding: when `REPRO_DIST_COORD` is set the process joins a
`jax.distributed` process group before any device query, the mesh spans
every host's devices (lane axis across hosts), batches are materialized as
global arrays via `jax.make_array_from_callback`, and `host_fetch` gathers
results back to every host (`multihost_utils.process_allgather`).  Without
the env knobs everything below is plain single-host jax.

Env knobs:

  REPRO_SWEEP_DEVICES   how many devices the sweep mesh uses: an integer,
                        or "all" (default).  Values outside 1..len(devices)
                        raise.
  REPRO_SWEEP_MESH      mesh shape as "LANESxSEEDS" (e.g. "2x2", "4x1"), or
                        "auto" (default).  The shape must factor the
                        selected device count exactly.
  REPRO_DIST_COORD      jax.distributed coordinator address (host:port);
                        unset = single-host (no process group is created).
  REPRO_DIST_NPROCS     number of processes in the group (with _COORD).
  REPRO_DIST_RANK       this process's rank in 0..NPROCS-1 (with _COORD).
"""
from __future__ import annotations

import os

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LANE_AXIS = "lanes"
SEED_AXIS = "seeds"
_ENV_DEVICES = "REPRO_SWEEP_DEVICES"
_ENV_MESH = "REPRO_SWEEP_MESH"
_ENV_COORD = "REPRO_DIST_COORD"
_ENV_NPROCS = "REPRO_DIST_NPROCS"
_ENV_RANK = "REPRO_DIST_RANK"

_dist_initialized = False


# ---------------------------------------------------------------------------
# Multi-host scaffolding
# ---------------------------------------------------------------------------

def maybe_init_distributed() -> bool:
    """Join the `jax.distributed` process group named by REPRO_DIST_COORD /
    REPRO_DIST_NPROCS / REPRO_DIST_RANK.  A no-op (returns False) when
    REPRO_DIST_COORD is unset — the single-host degradation — and idempotent
    once initialized.  Must run before the first device query, which is why
    `sweep_devices` calls it."""
    global _dist_initialized
    if _dist_initialized:
        return True
    coord = os.environ.get(_ENV_COORD, "").strip()
    if not coord:
        return False
    try:
        nprocs = int(os.environ[_ENV_NPROCS])
        rank = int(os.environ[_ENV_RANK])
    except KeyError as e:
        raise ValueError(
            f"{_ENV_COORD}={coord!r} is set but {e.args[0]} is not; "
            f"multi-host runs need {_ENV_NPROCS} and {_ENV_RANK}") from None
    except ValueError:
        raise ValueError(
            f"{_ENV_NPROCS}/{_ENV_RANK} must be integers (got "
            f"{os.environ.get(_ENV_NPROCS)!r}/{os.environ.get(_ENV_RANK)!r})"
        ) from None
    jax.distributed.initialize(coordinator_address=coord,
                               num_processes=nprocs, process_id=rank)
    _dist_initialized = True
    return True


def host_fetch(tree):
    """Bring a (possibly multi-host-sharded) pytree back to host numpy.

    Single-host (the common case): a plain `np.asarray` per leaf.  In a
    `jax.distributed` run the leaves are global arrays with non-addressable
    shards, so they are gathered across processes first — every host gets
    the full result, keeping the unfold/write-back logic host-agnostic.

    Note: the CPU backend (jax 0.4.37) cannot *execute* multiprocess
    computations ("Multiprocess computations aren't implemented on the CPU
    backend"), so on CPU the distributed path is exercised up to
    process-group init and global device visibility only — end-to-end
    multi-host dispatch needs a GPU/TPU backend."""
    if tree is None:
        return None
    if jax.process_count() > 1:
        from jax.experimental import multihost_utils
        tree = multihost_utils.process_allgather(tree, tiled=True)
    return jax.tree.map(np.asarray, tree)


# ---------------------------------------------------------------------------
# Device selection + mesh construction
# ---------------------------------------------------------------------------

def sweep_devices() -> list:
    """Devices the sweep mesh spans, honoring REPRO_SWEEP_DEVICES."""
    maybe_init_distributed()
    devices = jax.devices()
    raw = os.environ.get(_ENV_DEVICES, "all").strip().lower()
    if raw in ("", "all"):
        return devices
    try:
        n = int(raw)
    except ValueError:
        raise ValueError(
            f"{_ENV_DEVICES}={raw!r}: expected an integer or 'all'") from None
    if not 1 <= n <= len(devices):
        raise ValueError(f"{_ENV_DEVICES}={n} outside 1..{len(devices)} "
                         f"({len(devices)} {devices[0].platform} devices "
                         "visible)")
    return devices[:n]


def sweep_mesh_shape(n_devices: int) -> tuple[int, int] | None:
    """The (lane, seed) mesh shape forced by REPRO_SWEEP_MESH, or None when
    unset/"auto" (the execute layer then auto-factors per plan).

    The shape must factor `n_devices` exactly; anything else raises a
    ValueError naming the knob, the value and the available devices instead
    of surfacing an opaque mesh-construction error."""
    raw = os.environ.get(_ENV_MESH, "").strip().lower()
    if raw in ("", "auto"):
        return None
    parts = raw.split("x")
    try:
        if len(parts) != 2:
            raise ValueError
        dl, ds = int(parts[0]), int(parts[1])
        if dl < 1 or ds < 1:
            raise ValueError
    except ValueError:
        raise ValueError(
            f"{_ENV_MESH}={raw!r}: expected 'LANESxSEEDS' with two positive "
            "integers (e.g. '4x1', '2x2') or 'auto'") from None
    if dl * ds != n_devices:
        raise ValueError(
            f"{_ENV_MESH}={raw!r}: a {dl}x{ds} (lane x seed) mesh needs "
            f"{dl * ds} devices but {n_devices} device(s) are selected "
            f"(REPRO_SWEEP_DEVICES; {len(jax.devices())} visible) — the "
            "shape must factor the device count exactly")
    return dl, ds


def auto_mesh_shape(n_devices: int,
                    groups: list[tuple[int, int, int]]) -> tuple[int, int]:
    """Factor `n_devices` into the (lane, seed) dims that minimize total
    padded-cell work for a plan's groups.

    `groups` holds (n_lanes, n_seeds, weight) per group — weight is the
    per-cell cost proxy (episode count; every group shares the plan's op
    envelope).  Cost of a shape is Σ weight · pad(L, dl) · pad(S, ds); ties
    break toward the larger lane dim, so all-S=1 plans keep the historical
    1-D lane mesh exactly."""
    if n_devices <= 1:
        return (max(n_devices, 1), 1)

    def pad(n, d):
        return ((max(n, 1) + d - 1) // d) * d

    best = None
    for ds in range(1, n_devices + 1):
        if n_devices % ds:
            continue
        dl = n_devices // ds
        cost = sum(w * pad(L, dl) * pad(S, ds) for L, S, w in groups)
        key = (cost, ds)                 # ties -> smaller seed dim
        if best is None or key < best[0]:
            best = (key, (dl, ds))
    return best[1]


def build_mesh(devices=None, shape: tuple[int, int] | None = None
               ) -> Mesh | None:
    """2-D (lane, seed) mesh over `devices` (default: `sweep_devices()`).

    `shape` is (lane_dim, seed_dim); by default the REPRO_SWEEP_MESH
    override or, unset, the 1-D lane layout `(n, 1)` — callers with a plan
    in hand (sweep.run_grid) pass `auto_mesh_shape(...)` instead.  Returns
    None on a single device — the degraded path runs exactly the
    single-device program with no placement or padding."""
    devices = sweep_devices() if devices is None else list(devices)
    n = len(devices)
    if n <= 1:
        return None
    if shape is None:
        shape = sweep_mesh_shape(n) or (n, 1)
    dl, ds = int(shape[0]), int(shape[1])
    if dl * ds != n:
        raise ValueError(
            f"mesh shape {dl}x{ds} does not factor the {n} selected "
            f"device(s) ({len(jax.devices())} visible; see {_ENV_MESH})")
    return Mesh(np.asarray(devices).reshape(dl, ds), (LANE_AXIS, SEED_AXIS))


def mesh_desc(mesh: Mesh | None) -> dict:
    """JSON-friendly mesh description (benchmark records, memo keys)."""
    if mesh is None:
        return {"n_devices": 1, "shape": [1, 1],
                "axis_names": [LANE_AXIS, SEED_AXIS], "n_hosts": 1}
    return {"n_devices": int(mesh.devices.size),
            "shape": [int(s) for s in mesh.devices.shape],
            "axis_names": list(mesh.axis_names),
            "n_hosts": int(jax.process_count())}


def mesh_lane_dim(mesh: Mesh | None) -> int:
    return 1 if mesh is None else int(mesh.shape[LANE_AXIS])


def mesh_seed_dim(mesh: Mesh | None) -> int:
    return 1 if mesh is None else int(mesh.shape[SEED_AXIS])


def mesh_signature() -> str:
    """Stable signature of the mesh the next sweep would run on — part of
    grid memo keys so cached results never cross a mesh change (device
    count, forced shape, or host count)."""
    devices = sweep_devices()
    shape = os.environ.get(_ENV_MESH, "auto").strip().lower() or "auto"
    return (f"{devices[0].platform}:{len(devices)}:{shape}"
            f":{jax.process_count()}")


# ---------------------------------------------------------------------------
# Padding + placement
# ---------------------------------------------------------------------------

def padded_lane_count(n_lanes: int, mesh: Mesh | None) -> int:
    """Smallest lane count >= n_lanes divisible by the mesh's lane dim."""
    dl = mesh_lane_dim(mesh)
    return ((n_lanes + dl - 1) // dl) * dl


def padded_seed_count(n_seeds: int, mesh: Mesh | None) -> int:
    """Smallest seed width >= n_seeds divisible by the mesh's seed dim."""
    ds = mesh_seed_dim(mesh)
    return ((n_seeds + ds - 1) // ds) * ds


def pad_group_batch(batch: dict[str, np.ndarray],
                    n_to: int) -> dict[str, np.ndarray]:
    """Pad every lane-axis array to `n_to` lanes by repeating lane 0.

    Padding lanes are real, legal simulations (copies of lane 0) so the
    SPMD program needs no masking; the execute layer simply never reads
    their outputs."""
    if not batch:
        raise ValueError(
            "pad_group_batch: empty group batch (no arrays) — a group must "
            "hold at least one lane before it can be padded")
    n = next(iter(batch.values())).shape[0]
    if n_to == n:
        return batch
    assert n_to > n
    return {k: np.concatenate([v, np.repeat(v[:1], n_to - n, axis=0)])
            for k, v in batch.items()}


def pad_seed_axis(batch: dict[str, np.ndarray],
                  s_to: int) -> dict[str, np.ndarray]:
    """Pad the episode seed schedule's (L, S, E) seed axis to `s_to` slots
    by repeating slot 0 (padding slots re-simulate the lane's first seed;
    their outputs are dropped).  Only `ep_seed` carries a seed axis."""
    eps = batch["ep_seed"]
    if eps.shape[1] == s_to:
        return batch
    assert s_to > eps.shape[1]
    out = dict(batch)
    out["ep_seed"] = np.concatenate(
        [eps, np.repeat(eps[:, :1], s_to - eps.shape[1], axis=1)], axis=1)
    return out


def _put(arr, sharding):
    """Place one host array on the mesh; in a multi-host run the same host
    copy exists on every process, so each process contributes its
    addressable shards via `make_array_from_callback`."""
    if jax.process_count() > 1:
        arr = np.asarray(arr)
        return jax.make_array_from_callback(arr.shape, sharding,
                                            lambda idx: arr[idx])
    return jax.device_put(arr, sharding)


def shard_group_batch(batch: dict[str, np.ndarray], mesh: Mesh | None) -> dict:
    """Place a (padded) group batch: lane axis sharded, the episode seed
    schedule sharded over (lanes, seeds), trailing axes replicated.
    Without a mesh this is a plain host->device transfer."""
    import jax.numpy as jnp
    if mesh is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    lane_sh = NamedSharding(mesh, P(LANE_AXIS))
    cell_sh = NamedSharding(mesh, P(LANE_AXIS, SEED_AXIS))
    return {k: _put(v, cell_sh if k == "ep_seed" else lane_sh)
            for k, v in batch.items()}


def shard_agent_batch(agent, mesh: Mesh | None):
    """Place a flat lane-major (L*S, ...) agent cell batch: the flattened
    cell axis shards over both mesh axes (lane-major order matches the
    (L, S) layout of the env grid, so no resharding inside the program)."""
    if mesh is None or agent is None:
        return agent
    sh = NamedSharding(mesh, P((LANE_AXIS, SEED_AXIS)))
    return jax.tree.map(lambda a: _put(a, sh), agent)


def replicate(x, mesh: Mesh | None):
    """Replicate a lane-independent array (e.g. TOM candidate tables)."""
    if mesh is None:
        return x
    return jax.device_put(x, NamedSharding(mesh, P()))
