"""Partition layer of the sweep pipeline: device meshes + lane sharding.

Builds a 1-D `jax.sharding.Mesh` over the available devices and places a
group batch (see `nmp.plan.build_group_batch`) on it with the lane axis
sharded (`NamedSharding(P("lanes"))`) and everything lane-independent
replicated.  The execute layer's jitted program then runs SPMD across the
mesh: per-lane work never crosses a device, the only collectives are the
scalar "any lane invokes / profiles" reductions that feed the engine's
`lax.cond` gates, so sharded per-lane metrics are bit-identical to the
single-device run.

Lane counts are padded up to a device-divisible size by repeating the first
lane (padding lanes are simulated and dropped by the execute layer).

Degrades gracefully: with a single device (plain CPU CI) `build_mesh`
returns None and the execute layer skips placement entirely.  Multi-device
CPU testing is forced with `XLA_FLAGS=--xla_force_host_platform_device_count=N`
(set before importing jax).

Env knobs:

  REPRO_SWEEP_DEVICES   how many devices the sweep mesh uses: an integer,
                        or "all" (default).  Values outside 1..len(devices)
                        raise.
"""
from __future__ import annotations

import os

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LANE_AXIS = "lanes"
_ENV_DEVICES = "REPRO_SWEEP_DEVICES"


def sweep_devices() -> list:
    """Devices the sweep mesh spans, honoring REPRO_SWEEP_DEVICES."""
    devices = jax.devices()
    raw = os.environ.get(_ENV_DEVICES, "all").strip().lower()
    if raw in ("", "all"):
        return devices
    try:
        n = int(raw)
    except ValueError:
        raise ValueError(
            f"{_ENV_DEVICES}={raw!r}: expected an integer or 'all'") from None
    if not 1 <= n <= len(devices):
        raise ValueError(f"{_ENV_DEVICES}={n} outside 1..{len(devices)} "
                         f"({len(devices)} {devices[0].platform} devices "
                         "visible)")
    return devices[:n]


def build_mesh(devices=None) -> Mesh | None:
    """1-D lane mesh over `devices` (default: `sweep_devices()`).

    Returns None on a single device — the degraded path runs exactly the
    PR 2 single-device program with no placement or padding."""
    devices = sweep_devices() if devices is None else list(devices)
    if len(devices) <= 1:
        return None
    return Mesh(np.asarray(devices), (LANE_AXIS,))


def mesh_desc(mesh: Mesh | None) -> dict:
    """JSON-friendly mesh description (benchmark records, memo keys)."""
    if mesh is None:
        return {"n_devices": 1, "shape": [1], "axis_names": [LANE_AXIS]}
    return {"n_devices": int(mesh.devices.size),
            "shape": [int(s) for s in mesh.devices.shape],
            "axis_names": list(mesh.axis_names)}


def mesh_signature() -> str:
    """Stable signature of the mesh the next sweep would run on — part of
    grid memo keys so cached results never cross a mesh change."""
    devices = sweep_devices()
    return f"{devices[0].platform}:{len(devices)}"


def padded_lane_count(n_lanes: int, mesh: Mesh | None) -> int:
    """Smallest device-divisible lane count >= n_lanes."""
    if mesh is None:
        return n_lanes
    n_dev = int(mesh.devices.size)
    return ((n_lanes + n_dev - 1) // n_dev) * n_dev


def pad_group_batch(batch: dict[str, np.ndarray],
                    n_to: int) -> dict[str, np.ndarray]:
    """Pad every lane-axis array to `n_to` lanes by repeating lane 0.

    Padding lanes are real, legal simulations (copies of lane 0) so the
    SPMD program needs no masking; the execute layer simply never reads
    their outputs."""
    if not batch:
        raise ValueError(
            "pad_group_batch: empty group batch (no arrays) — a group must "
            "hold at least one lane before it can be padded")
    n = next(iter(batch.values())).shape[0]
    if n_to == n:
        return batch
    assert n_to > n
    return {k: np.concatenate([v, np.repeat(v[:1], n_to - n, axis=0)])
            for k, v in batch.items()}


def shard_group_batch(batch: dict[str, np.ndarray], mesh: Mesh | None) -> dict:
    """Place a (padded) group batch: lane axis sharded, trailing axes
    replicated.  Without a mesh this is a plain host->device transfer."""
    import jax.numpy as jnp
    if mesh is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    lane_sh = NamedSharding(mesh, P(LANE_AXIS))
    return {k: jax.device_put(v, lane_sh) for k, v in batch.items()}


def replicate(x, mesh: Mesh | None):
    """Replicate a lane-independent array (e.g. TOM candidate tables)."""
    if mesh is None:
        return x
    return jax.device_put(x, NamedSharding(mesh, P()))
