"""NMP system hardware configuration (paper Table 1) and timing constants.

The paper's system: 16-core CMP, 4 memory controllers at the CMP corners,
a 4x4 (scalability study: 8x8) mesh of 1 GB memory cubes (32 vaults x 8 banks,
crossbar), 3-stage routers, 128-bit links, 512-entry NMP-op tables, 128-entry
page-info caches (empirically bumped to 256 in §7.6).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class NMPConfig:
    # --- topology (Table 1) ---
    # `topology` names a builder in nmp.topology.TOPOLOGIES ("mesh2d",
    # "torus2d", "ring", "dragonfly"); mesh_x/mesh_y parameterize its
    # geometry (ring: mesh_x*mesh_y cubes; dragonfly: mesh_y groups of
    # mesh_x cubes).  The routing tensors are precomputed host-side from
    # this declarative spec (nmp.topology.get_topology), so the config stays
    # hashable and jit-static.
    topology: str = "mesh2d"
    mesh_x: int = 4
    mesh_y: int = 4
    n_mcs: int = 4                    # one per CMP corner
    # --- cube internals ---
    n_vaults: int = 32
    banks_per_vault: int = 8
    nmp_table_size: int = 512         # outstanding NMP-op entries per cube
    # --- AIMM hardware ---
    page_cache_entries: int = 256     # page info cache (empirical, §7.6)
    migration_queue: int = 128
    # page-info-cache history depths (paper Fig. 3; per-page hop / latency /
    # migration-latency / action histories).  Also sizes the matching state-
    # vector slices (core.state.StateSpec), so changing them changes the DQN
    # input dim.
    hop_hist: int = 8
    lat_hist: int = 8
    mig_hist: int = 4
    act_hist: int = 4
    # --- memory / network geometry ---
    page_bytes: int = 4096
    link_bytes_per_cycle: int = 16    # 128-bit links
    packet_bytes: int = 64            # one NMP data packet (cacheline)
    # --- timing model (cycles) ---
    t_router: float = 3.0             # 3-stage router pipeline per hop
    t_dram_hit: float = 15.0          # row-buffer hit access
    t_dram_miss: float = 45.0         # row activate + access
    t_op: float = 2.0                 # NMP compute service per op
    cube_issue_rate: float = 4.0      # ops/cycle a cube can drain (vault parallelism)
    mc_issue_rate: float = 2.0        # ops/cycle each MC can inject
    t_agent: float = 4.0              # AIMM action-application overhead per epoch
                                      # (agent inference runs concurrently on its
                                      #  own accelerator, §5.2 — non-blocking)
    congestion_alpha: float = 1.6     # queuing amplification on the hottest link
                                      # (M/M/1-style superlinear contention)
    t_page_walk: float = 4.0          # amortized 4-level page walk (TLB-filtered)
    # --- epochs & agent invocation intervals ---
    # Fixed-size op windows; the paper's interval actions ({100,125,167,250}
    # cycles) map to invocation strides of {1,2,3,4} epochs.
    epoch_ops: int = 128
    w_max: int = 128                  # static op-window buffer (== epoch_ops)
    # --- migration ---
    mig_blocking_stall: float = 96.0  # extra stall for blocking (RW) migration
    mig_nonblocking_stall: float = 16.0
    # --- PEI cache model ---
    pei_hot_frac: float = 0.05        # top-5% hottest pages count as CPU-cache hits
    # --- AIMM hot-page selection ---
    recent_ring: int = 2              # skip pages acted on in the last N epochs
    remap_ttl: int = 64               # compute-remap table entry lifetime (epochs)

    @property
    def n_cubes(self) -> int:
        return self.mesh_x * self.mesh_y

    @property
    def page_flits(self) -> float:
        return self.page_bytes / self.link_bytes_per_cycle  # cycles on one link

    @property
    def packet_flits(self) -> float:
        return self.packet_bytes / self.link_bytes_per_cycle

    @property
    def mc_cubes(self) -> tuple[int, ...]:
        """Cube ids adjacent to each MC (the four mesh corners)."""
        X, Y = self.mesh_x, self.mesh_y
        return (0, X - 1, X * (Y - 1), X * Y - 1)


# Energy constants (paper §7.7, CACTI 45nm + published per-bit figures).
ENERGY_NJ = {
    "page_cache_access": 0.05,
    "nmp_buffer_access": 0.122,
    "mig_queue_access": 0.02689,
    "mdma_access": 0.1062,
    "weight_access": 0.244,
    "replay_access": 2.3,
    "state_buffer_access": 0.106,
    "network_per_bit_hop": 0.005,   # 5 pJ/bit/hop
    "memory_per_bit": 0.012,        # 12 pJ/bit/access
}

AREA_MM2 = {
    "page_info_cache": 0.23,   # 64 KB
    "nmp_buffer": 0.14,        # 512 B
    "migration_queue": 0.04,   # 2 KB
    "mdma_buffers": 0.124,     # 1 KB
    "weight_matrix": 2.095,    # 603 KB
    "replay_buffer": 117.86,   # 36 MB
    "state_buffer": 0.12,      # 576 B
}
