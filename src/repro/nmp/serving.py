"""Streaming multi-tenant mapping service: many concurrent tenant streams
through a small set of resident compiled lane-slot programs.

The paper's mapper is *continual* — it keeps learning "for any application"
— but `continual.run_stream` is an offline, one-stream batch loop.  This
module is the long-lived serving layer the north star asks for:

  MappingServer : holds `n_slots` lane slots and one bounded `PolicyStore`.
                  Tenants (`submit(tenant_id, stream)`) queue for a slot;
                  a slot executes one phase of its tenant's stream per
                  service tick and is recycled when the tenant's stream is
                  drained (or the tenant is `remove`d mid-stream).  Each
                  tick batches the current phase of every active tenant
                  into ONE `run_grid`-shaped compiled call, reusing the
                  plan / partition / sweep pipeline with a *forced*
                  `plan.Envelope` and a *fixed* padded lane count — so the
                  resident programs' static shapes never change as tenants
                  arrive and depart, and nothing recompiles at steady state
                  (`sweep.compiled_sweep_programs` tracks this).

Scheduling and exactness: every slot is an independent lane of the sweep,
and per-lane results are bit-identical to serial runs regardless of padding
envelope or co-lanes (the pipeline's standing invariant), so a tenant's
per-phase metrics are bit-identical to running its stream alone via
`continual.run_stream` with the same lineage tag (tests/test_serving.py).
Agent continuity goes through the shared `PolicyStore` exactly as in
`run_grid` — the tenant id is the lineage tag — so a bounded store with LRU
eviction serves an unbounded tenant population: an evicted tenant's next
phase transparently cold-restarts its lineage.

Double buffering: the compiled call is dispatched asynchronously and the
*next* tick's host batch is built and transferred (`jax.device_put` inside
`sweep.prepare_group_batch`) while the devices execute the current one, so
the engine never idles on host->device I/O.  The schedule of tick t+1 is a
pure function of the queue/slot bookkeeping — it never waits on tick t's
results; only the warm agent batch does.

Fault tolerance — the tenant health state machine:

  healthy ──failure──> degraded ──(> max_phase_retries failures)──> quarantined
     ^                    │
     └────one success─────┘

A *failure* is any of: the lane's completed tick diverged (the once-per-tick
batched `isfinite` guard over per-lane float metrics and final agent params,
see `sweep.lane_finite_mask` — checked at host sync, never per epoch); an
injected/attributed tick exception (`faults.InjectedFault`); or the tick
overran `phase_deadline_s` with the stall attributed to the tenant.  A
failed phase attempt is *not* consumed: the tenant's cursor rewinds, its
result is discarded, its agent is NOT written to the store, and the phase is
retried after an exponential backoff (`backoff_base_s * 2**(retries-1)`).
If the tenant's *stored* snapshot itself is non-finite (silent store
corruption), the lineage first rolls back to its last-good PolicyStore
version (`PolicyStore.rollback`).  After `max_phase_retries` consecutive
failures the tenant is quarantined: removed from the slot schedule (its
slot recycles to the queue) and never scheduled again, while every other
tenant's results remain bit-identical to a fault-free run — lanes are
independent, retried compiled calls are deterministic, and a transient
fault's retry therefore reproduces the fault-free result exactly.

Removal semantics: `remove()` marks the tenant; a phase already sitting in
the double-buffered prepared batch is *dropped on advance* — its lane still
executes (static shapes), but its result is discarded and its agent is not
written back, so nothing a removed tenant did after removal is observable.

Fault injection: pass a `faults.FaultPlan` to arm deterministic faults
(poisoned warm agents, failed/stalled ticks, shrunken device visibility) at
explicit hook sites; with `faults=None` every hook site is a single `is
not None` check, and the only standing cost is the once-per-tick finite
guard (disable with `divergence_guard=False`; measured < 2% in
benchmarks/bench_faults.py).

Metrics: `MappingServer.stats()` reports per-phase latency p50/p99 over
steady-state ticks (compile ticks are excluded from the percentiles and
their total wall is reported separately as `compile_s`),
steady-state epochs/sec (ticks after the last compile), slot occupancy,
recompile and eviction counts, plus fault/retry/quarantine/rollback/
fallback counters — the records `benchmarks/bench_serving.py` and
`benchmarks/bench_faults.py` write to bench_out/.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Sequence

import jax
import numpy as np

from repro.kernels.epoch_fused import ops as epoch_ops
from repro.nmp import baselines, partition
from repro.nmp import faults as faults_mod
from repro.nmp import plan as plan_mod
from repro.nmp import sweep as sweep_mod
from repro.nmp.config import NMPConfig
from repro.nmp.continual import PolicyStore, check_tag
from repro.nmp.engine import (BodyFlags, default_agent_cfg, pei_top_k,
                              state_spec_for)
from repro.nmp.faults import FaultPlan, InjectedFault
from repro.nmp.plan import (Envelope, needs_agent, plan_envelope, plan_grid,
                            seed_share_enabled)
from repro.nmp.scenarios import Scenario
from repro.nmp.sweep import SweepResult


def solo_stream(tenant_id: str,
                stream: Sequence[Sequence[Scenario] | Scenario]
                ) -> list[list[Scenario]]:
    """The reference protocol for one tenant: its stream re-tagged exactly
    as the server tags it (lineage == tenant id), runnable standalone via
    `continual.run_stream`.  A tenant's per-phase serving results are
    bit-identical to this solo run's."""
    return [[dataclasses.replace(_phase_scenario(ph), lineage=tenant_id)]
            for ph in stream]


def _phase_scenario(phase) -> Scenario:
    """Normalize one stream phase to its single scenario (serving slots are
    one lane wide; a phase may be a Scenario or a [Scenario])."""
    if isinstance(phase, Scenario):
        return phase
    phase = list(phase)
    if len(phase) != 1:
        raise ValueError(
            f"serving streams are single-lane: each phase must hold exactly "
            f"one scenario (got {len(phase)})")
    return phase[0]


@dataclasses.dataclass
class Tenant:
    """Bookkeeping for one submitted tenant stream."""
    tenant_id: str
    phases: list[Scenario]           # re-tagged, one scenario per phase
    cursor: int = 0                  # next phase to serve
    slot: int | None = None
    done: bool = False
    removed: bool = False
    health: str = "healthy"          # healthy | degraded | quarantined
    quarantined: bool = False
    retries: int = 0                 # consecutive failed attempts
    backoff_until: float = 0.0       # monotonic time gating the next attempt
    last_error: str | None = None
    latencies: list = dataclasses.field(default_factory=list)
    results: list = dataclasses.field(default_factory=list)
                                     # per served phase: (SweepResult, lane)

    @property
    def remaining(self) -> int:
        return len(self.phases) - self.cursor

    @property
    def stale(self) -> bool:
        """True when a prepared-batch entry for this tenant must be dropped
        (removed or quarantined after the batch was built)."""
        return self.removed or self.quarantined


class MappingServer:
    """Long-lived multi-tenant mapping service (see module docstring).

    `n_slots` is rounded up to the device-mesh width, so slot-sharded
    serving works unchanged on a forced multi-device host.  `envelope`
    fixes the resident programs' padded shapes up front; by default it is
    inferred (and frozen) from everything submitted before the first tick,
    and later submissions must fit it.  `store` (or `store_capacity`)
    bounds the lineage store; `keep_results=False` drops per-phase metric
    arrays after recording latencies (long-running servers).

    Robustness knobs: `divergence_guard` runs the once-per-tick finite
    check; `max_phase_retries` bounds consecutive failed attempts before a
    tenant is quarantined; `backoff_base_s` seeds the exponential retry
    backoff; `phase_deadline_s` flags ticks that overran their deadline
    (an attributed stall counts as a failed attempt for that tenant);
    `faults` arms a deterministic `faults.FaultPlan` (tests/benchmarks)."""

    def __init__(self, cfg: NMPConfig = NMPConfig(), n_slots: int = 8,
                 envelope: Envelope | None = None,
                 agent_cfg=None, store: PolicyStore | None = None,
                 store_capacity: int | None = None,
                 keep_results: bool = True,
                 divergence_guard: bool = True,
                 max_phase_retries: int = 2,
                 backoff_base_s: float = 0.02,
                 phase_deadline_s: float | None = None,
                 faults: FaultPlan | None = None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1 (got {n_slots})")
        if max_phase_retries < 0:
            raise ValueError(
                f"max_phase_retries must be >= 0 (got {max_phase_retries})")
        self.cfg = cfg
        self.mesh = partition.build_mesh()
        self.n_slots = partition.padded_lane_count(n_slots, self.mesh)
        # Tenants never fold, so every group is seed-width 1; a mesh with a
        # seed axis wider than 1 (REPRO_SWEEP_MESH=LxS) pads the executed
        # width up and the padding replicas' outputs are dropped.
        self.spec = state_spec_for(cfg)
        self.agent_cfg = agent_cfg or default_agent_cfg(cfg)
        if store is not None and store_capacity is not None:
            raise ValueError("pass either store or store_capacity, not both")
        self.store = (store if store is not None
                      else PolicyStore(capacity=store_capacity))
        self.envelope = envelope
        self.keep_results = keep_results
        self.guard = divergence_guard
        self.max_phase_retries = max_phase_retries
        self.backoff_base_s = backoff_base_s
        self.phase_deadline_s = phase_deadline_s
        self.faults = faults

        self._tenants: dict[str, Tenant] = {}
        self._queue: deque[str] = deque()
        self._slots: list[str | None] = [None] * self.n_slots
        self._episodes: int | None = (envelope.n_episodes
                                      if envelope is not None else None)
        self._flags = BodyFlags(has_agent=True, any_aimm=True, any_tom=False,
                                pei_k=0,
                                epoch_backend=epoch_ops.resolve_backend())
        self._tom_cands = None
        self._pending = None             # prepared-but-unserved next tick
        # Memo of host-side per-lane batch arrays keyed by trace identity:
        # an unchanged phase re-entering the resident shape re-uses the
        # seed-invariant arrays instead of re-quantizing the trace per tick.
        self._host_cache: dict = {}
        # Persistent staging buffers for the per-tick warm agent stacking:
        # the resident envelope fixes the cell count and leaf shapes, so in
        # steady state every tick refills the same host buffers and pays one
        # device transfer per agent leaf (REPRO_STORE_STAGING=off falls back
        # to the historical per-cell stacking).
        self._staging = (sweep_mod.AgentStaging()
                         if sweep_mod.staging_enabled() else None)
        # service metrics
        self.ticks = 0
        self._attempts = 0               # dispatch attempts (ticks + retries)
        self._tick_wall: list[float] = []
        self._tick_active: list[int] = []
        self._tick_compiles: list[int] = []
        self._phases_served = 0
        # fault / recovery counters
        self._tick_failures = 0          # dispatch attempts that raised
        self._global_failure_streak = 0  # consecutive unattributed failures
        self._divergences = 0            # non-finite lanes caught by guard
        self._deadline_misses = 0        # ticks over phase_deadline_s
        self._retries_total = 0
        self._quarantines = 0
        self._stale_dropped = 0          # prepared entries dropped on advance
        self._device_shrinks = 0
        self._validation_rejects = 0

    # -- tenant lifecycle ----------------------------------------------

    def submit(self, tenant_id: str,
               stream: Sequence[Sequence[Scenario] | Scenario]) -> None:
        """Enqueue a tenant stream.  The tenant id becomes the lineage tag
        of every phase (duplicate ids — which would silently share one DQN
        across tenants — are rejected while the earlier tenant is live).
        Streams are validated at this boundary: malformed traces (NaN/Inf,
        negative or out-of-range page ids, empty op/page counts) raise a
        `ValueError` naming the tenant and phase instead of flowing into
        the compiled program."""
        check_tag(tenant_id)
        prev = self._tenants.get(tenant_id)
        if prev is not None and not prev.done and not prev.quarantined:
            raise ValueError(
                f"tenant {tenant_id!r} is already live (queued or in a "
                "slot); duplicate lineage tags would share one DQN across "
                "tenants — wait for it to drain or pick a distinct id")
        phases = [dataclasses.replace(_phase_scenario(ph),
                                      lineage=tenant_id) for ph in stream]
        if not phases:
            raise ValueError(f"tenant {tenant_id!r}: empty stream")
        for pi, sc in enumerate(phases):
            try:
                self._validate_scenario(tenant_id, pi, sc)
            except ValueError:
                self._validation_rejects += 1
                raise
        for sc in phases:
            self._absorb_flags(sc)
        self._tenants[tenant_id] = Tenant(tenant_id=tenant_id, phases=phases)
        self._queue.append(tenant_id)
        self._pending = None             # schedule changed; re-prepare

    def remove(self, tenant_id: str) -> None:
        """Depart a tenant mid-stream: frees its slot (or queue entry)
        immediately.  A phase of the tenant already sitting in the prepared
        (double-buffered) next batch is dropped on advance — it can neither
        complete into `results` nor write its agent back to the store.  The
        lineage stays in the store until evicted."""
        t = self._tenants[tenant_id]
        if t.done:
            return
        t.done = t.removed = True
        if t.slot is not None:
            self._slots[t.slot] = None
            t.slot = None
            # the prepared batch (if any) may still hold this tenant's
            # phase: kept — its entry is stale-dropped at advance/complete
        else:
            self._queue = deque(q for q in self._queue if q != tenant_id)

    def _validate_scenario(self, tenant_id: str, phase_idx: int,
                           sc: Scenario) -> None:
        self._validate_trace(tenant_id, phase_idx, sc)
        if not needs_agent(sc):
            raise ValueError(
                f"tenant {tenant_id!r}: serving slots run learned-AIMM "
                f"lanes (got mapper={sc.mapper!r}, "
                f"forced_action={sc.forced_action})")
        if sc.topology is not None and sc.topology != self.cfg.topology:
            raise ValueError(
                f"tenant {tenant_id!r}: scenario topology {sc.topology!r} "
                f"differs from the server's {self.cfg.topology!r}; one "
                "resident program serves one interconnect")
        if self._episodes is None:
            self._episodes = sc.total_episodes
        elif sc.total_episodes != self._episodes:
            raise ValueError(
                f"tenant {tenant_id!r}: phase runs {sc.total_episodes} "
                f"episodes but the server's resident programs are fixed at "
                f"{self._episodes}; all tenants must share one phase "
                "episode count")
        if self.envelope is not None:
            need = plan_envelope([sc], self.cfg)
            if not self.envelope.dominates(need):
                raise ValueError(
                    f"tenant {tenant_id!r}: phase needs envelope {need} "
                    f"but the server's is frozen at {self.envelope}")

    def _validate_trace(self, tenant_id: str, phase_idx: int,
                        sc: Scenario) -> None:
        """Input validation at the submit boundary: reject trace arrays that
        would silently flow into the compiled program as garbage."""
        tr = sc.trace
        where = f"tenant {tenant_id!r} phase {phase_idx} ({sc.name!r})"
        if tr.n_pages <= 0:
            raise ValueError(f"{where}: non-positive page count "
                             f"{tr.n_pages}")
        if tr.n_ops <= 0:
            raise ValueError(f"{where}: empty op trace")
        for field in ("dest", "src1", "src2"):
            a = np.asarray(getattr(tr, field))
            if np.issubdtype(a.dtype, np.floating):
                if not np.isfinite(a).all():
                    raise ValueError(
                        f"{where}: trace {field!r} contains NaN/Inf entries")
            if a.size and int(a.min()) < 0:
                raise ValueError(
                    f"{where}: trace {field!r} contains negative page ids")
            if a.size and int(a.max()) >= tr.n_pages:
                raise ValueError(
                    f"{where}: trace {field!r} references page "
                    f"{int(a.max())} outside the {tr.n_pages}-page space")

    def _absorb_flags(self, sc: Scenario) -> None:
        """Grow the resident programs' static BodyFlags monotonically (a new
        capability — e.g. the first PEI tenant — recompiles once; the flags
        stay a superset of every lane's needs, which the engine's per-lane
        gating makes exact)."""
        if sc.technique == "pei":
            k = pei_top_k(sc.trace.n_pages, self.cfg)
            if k > self._flags.pei_k:
                self._flags = dataclasses.replace(self._flags, pei_k=k)
                self._pending = None

    # -- scheduling ----------------------------------------------------

    def _freeze_envelope(self) -> None:
        if self.envelope is None:
            scs = [sc for t in self._tenants.values() if not t.done
                   for sc in t.phases]
            env = plan_envelope(scs, self.cfg)
            # phase episode counts are uniform (enforced at submit)
            self.envelope = dataclasses.replace(env,
                                                n_episodes=self._episodes)
        if self._tom_cands is None:
            self._tom_cands = partition.replicate(
                baselines.tom_candidates(self.envelope.n_pages_max, self.cfg),
                self.mesh)

    def _schedule(self) -> list[tuple[int, Tenant]]:
        """Assign queued tenants to free slots and return the active
        (slot, tenant) pairs in slot order — the lane order of the tick's
        compiled call.  Pure bookkeeping: never waits on device results.
        Slot holders inside their retry backoff window are skipped (their
        slot idles until the backoff expires)."""
        now = time.monotonic()
        for i, tid in enumerate(self._slots):
            if tid is None and self._queue:
                nxt = self._queue.popleft()
                self._slots[i] = nxt
                self._tenants[nxt].slot = i
        return [(i, self._tenants[tid])
                for i, tid in enumerate(self._slots)
                if tid is not None
                and self._tenants[tid].backoff_until <= now]

    def _backoff_wait(self) -> bool:
        """When every slotted tenant is inside its backoff window, sleep
        until the earliest one expires.  True if a wait happened."""
        waits = [self._tenants[tid].backoff_until - time.monotonic()
                 for tid in self._slots if tid is not None]
        waits = [w for w in waits if w > 0]
        if not waits:
            return False
        time.sleep(min(waits) + 1e-4)
        return True

    def _prepare_next(self):
        """Build (and host->device transfer) the next tick's batch, or None
        when no tenant has work.  Callable while a previous tick is still
        executing on device (double buffering)."""
        sched = self._schedule()
        if not sched and self._backoff_wait():
            sched = self._schedule()
        if not sched:
            return None
        self._freeze_envelope()
        scs = [t.phases[t.cursor] for _, t in sched]
        plan = plan_grid(scs, self.cfg, envelope=self.envelope)
        groups = [g for g in plan.groups if g.n_lanes]
        assert len(groups) == 1, "serving lanes form one lineage group"
        group = groups[0]
        # Plan lanes are cost-sorted for shard packing, so lane position no
        # longer equals schedule position; tenants never fold (distinct
        # lineage tags), so each lane maps back to exactly one sched entry.
        lane_of = [0] * len(sched)
        for li, lane in enumerate(group.lanes):
            lane_of[lane.indices[0]] = li
        batch, _ = sweep_mod.prepare_group_batch(plan, group, self.cfg,
                                                 self.mesh,
                                                 n_lanes=self.n_slots,
                                                 host_cache=self._host_cache)
        return (sched, scs, plan, group, batch, lane_of)

    def _advance(self, sched: list[tuple[int, Tenant]]) -> None:
        """Consume the served phase of every scheduled tenant and recycle
        the slots of drained tenants (deterministic — usable before the
        tick's results land).  Entries whose tenant was removed or
        quarantined after the batch was prepared are dropped here: their
        phase is NOT consumed and their lane's result will be discarded."""
        for slot, t in sched:
            if t.stale:
                continue
            t.cursor += 1
            if t.cursor >= len(t.phases):
                t.done = True
                t.slot = None
                self._slots[slot] = None

    # -- fault handling ------------------------------------------------

    def _maybe_shrink(self) -> bool:
        """Apply an armed shrink_devices fault: rebuild the mesh over the
        surviving devices.  The resident slot count is fixed, so it must
        stay divisible by the new width; the next dispatch re-places (one
        recompile) and per-lane results stay bit-identical — the partition
        layer's standing invariant."""
        if self.faults is None:
            return False
        keep = self.faults.shrink_devices_now(self._attempts)
        if keep is None:
            return False
        devs = partition.sweep_devices()
        keep = max(1, min(int(keep), len(devs)))
        if self.n_slots % keep:
            raise ValueError(
                f"cannot shrink to {keep} devices: the resident slot count "
                f"{self.n_slots} must stay device-divisible")
        # Shrink to a lane-only mesh explicitly: a REPRO_SWEEP_MESH override
        # was shaped for the full device count and would not factor `keep`.
        self.mesh = partition.build_mesh(devs[:keep], shape=(keep, 1))
        self._tom_cands = None           # re-replicated on next freeze
        self._device_shrinks += 1
        self._pending = None             # placed on the old mesh; rebuild
        return True

    def _degrade(self, t: Tenant, reason: str) -> None:
        """One failed phase attempt: bounded retry with exponential backoff,
        escalating to quarantine."""
        t.retries += 1
        t.last_error = reason
        self._retries_total += 1
        if t.retries > self.max_phase_retries:
            self._quarantine(t, reason)
        else:
            t.health = "degraded"
            t.backoff_until = (time.monotonic()
                               + self.backoff_base_s * 2 ** (t.retries - 1))

    def _quarantine(self, t: Tenant, reason: str) -> None:
        """Remove a repeatedly failing tenant from the slot schedule for
        good; every other tenant keeps serving."""
        t.health = "quarantined"
        t.quarantined = True
        t.last_error = reason
        self._quarantines += 1
        if t.slot is not None:
            self._slots[t.slot] = None
            t.slot = None
        else:
            self._queue = deque(q for q in self._queue
                                if q != t.tenant_id)

    def _rewind(self, t: Tenant, reason: str) -> None:
        """Un-consume a diverged/stalled lane's phase (the advance already
        ran) so the attempt can be retried, triaging the stored snapshot:
        a non-finite store entry rolls the lineage back to its last-good
        version first."""
        t.cursor -= 1
        if t.done:                       # advance drained it; revive
            t.done = False
            self._queue.appendleft(t.tenant_id)
        tag = t.tenant_id
        if tag in self.store and not faults_mod.params_finite(
                self.store.get(tag)):
            self.store.rollback(tag)
        self._degrade(t, reason)

    def _fail_attempt(self, sched, tenant_id: str | None,
                      reason: str) -> None:
        """A dispatch attempt raised before completing.  Attributed faults
        degrade only their tenant; unattributed ones are retried whole-tick
        with a bounded consecutive-failure budget."""
        self._tick_failures += 1
        if tenant_id is not None and tenant_id in self._tenants:
            self._global_failure_streak = 0
            self._degrade(self._tenants[tenant_id], reason)
            return
        self._global_failure_streak += 1
        if self._global_failure_streak > self.max_phase_retries:
            raise InjectedFault(
                f"service tick failed {self._global_failure_streak} "
                f"consecutive times without tenant attribution: {reason}")
        time.sleep(self.backoff_base_s
                   * 2 ** (self._global_failure_streak - 1))

    # -- serving -------------------------------------------------------

    def _serve_one(self, prepared, overlap: bool):
        sched, scs, plan, group, batch, lane_of = prepared
        tenant_ids = [t.tenant_id for _, t in sched]
        attempt = self._attempts
        self._attempts += 1
        s_pad = int(batch["ep_seed"].shape[1])   # executed seed width
        warm = sweep_mod._warm_agent_batch(group, self.n_slots, self.store,
                                           self.agent_cfg, n_seeds=s_pad,
                                           mesh=self.mesh,
                                           staging=self._staging)
        stalled: tuple[str, ...] = ()
        if self.faults is not None:
            # poison indexes cells by position in the tenants list, which
            # must therefore follow lane (not schedule) order
            lane_tenants = [tenant_ids[lane.indices[0]]
                            for lane in group.lanes]
            warm = self.faults.poison_warm_agents(attempt, lane_tenants,
                                                  warm, s_pad)
        n_prog0 = sweep_mod.compiled_sweep_programs()
        t0 = time.perf_counter()
        try:
            if self.faults is not None:
                stalled = self.faults.on_dispatch(attempt, tenant_ids)
            out, _env_fin, agent_fin = sweep_mod.dispatch_sweep(
                batch, self._tom_cands, self.cfg, self.spec, self.agent_cfg,
                self.envelope.n_epochs, group.n_episodes,
                self.envelope.ring_len,
                self._flags._replace(
                    share_seed_inv=s_pad > 1 and seed_share_enabled()),
                warm_agent=warm, want_agent=True)
            self._advance(sched)
            # the devices are executing this tick: overlap the next tick's
            # host batch build + transfer with it
            nxt = self._prepare_next() if overlap else None
            out = jax.block_until_ready(out)
            agent_fin = jax.block_until_ready(agent_fin)
        except InjectedFault as e:
            self._fail_attempt(sched, e.tenant, str(e))
            return self._prepare_next() if overlap else None
        wall = time.perf_counter() - t0
        self._global_failure_streak = 0
        dirty = self._complete(sched, scs, out, agent_fin, group, wall,
                               sweep_mod.compiled_sweep_programs() - n_prog0,
                               stalled, s_pad, lane_of)
        if dirty:
            # a lane failed after the next batch was prepared: its schedule
            # (and the failed tenant's cursor) changed — rebuild
            nxt = self._prepare_next() if overlap else None
        return nxt

    def _complete(self, sched, scs, out, agent_fin, group, wall: float,
                  compiles: int, stalled: Sequence[str] = (),
                  s_pad: int = 1,
                  lane_of: Sequence[int] | None = None) -> bool:
        # s_pad is the *executed* seed width: logically always 1 (tenants
        # never fold together) but padded up to the mesh seed dim; the
        # padding replicas repeat seed 0 and slot 0 of each lane is real.
        missed = (self.phase_deadline_s is not None
                  and wall > self.phase_deadline_s)
        if missed:
            self._deadline_misses += 1
        if lane_of is None:
            lane_of = list(range(len(sched)))
        finite = (sweep_mod.lane_finite_mask(out, agent_fin, len(sched),
                                             s_pad)
                  if self.guard else np.ones(len(sched), bool))
        res = SweepResult(
            scenarios=scs, cfg=self.cfg,
            metrics={k: np.stack([np.asarray(v[lane_of[li], 0]) for li in
                                  range(len(sched))]) for k, v in out.items()},
            final_env=None, n_episodes=group.n_episodes, wall_s=wall)
        served = 0
        dirty = False
        for li, (slot, t) in enumerate(sched):
            if t.stale:                  # removed/quarantined after prepare
                self._stale_dropped += 1
                continue
            if not finite[lane_of[li]]:
                self._divergences += 1
                self._rewind(t, f"divergence: non-finite metrics or agent "
                                f"params in phase {t.cursor - 1}")
                dirty = True
                continue
            if missed and t.tenant_id in stalled:
                self._rewind(t, f"deadline: tick ran {wall:.3f}s > "
                                f"{self.phase_deadline_s}s (attributed "
                                "stall)")
                dirty = True
                continue
            cell = jax.tree.map(
                lambda a, li=li: np.asarray(a[lane_of[li] * s_pad]),
                agent_fin)
            self.store.put(t.tenant_id, cell, scenario=scs[li].name,
                           tenant=t.tenant_id)
            t.latencies.append(wall)
            if self.keep_results:
                t.results.append((res, li))
            t.retries = 0
            t.health = "healthy"
            t.backoff_until = 0.0
            served += 1
        self.ticks += 1
        self._phases_served += served
        self._tick_wall.append(wall)
        self._tick_active.append(served)
        self._tick_compiles.append(compiles)
        return dirty

    def tick(self) -> int:
        """Run one synchronous service step.  Returns the number of tenant
        phases served (0 = no work pending)."""
        self._maybe_shrink()
        prepared = self._pending or self._prepare_next()
        self._pending = None
        if prepared is None:
            return 0
        before = self._phases_served
        self._serve_one(prepared, overlap=False)
        return self._phases_served - before

    def run(self, max_ticks: int | None = None) -> int:
        """Drain every submitted stream, double-buffering the next tick's
        host batch against the current device step.  Returns dispatch
        attempts run (ticks + retries)."""
        n = 0
        while True:
            if self._maybe_shrink() or self._pending is None:
                self._pending = self._prepare_next()
            if self._pending is None:
                break
            if max_ticks is not None and n >= max_ticks:
                break
            self._pending = self._serve_one(self._pending, overlap=True)
            n += 1
        return n

    # -- results & metrics ---------------------------------------------

    def tenant(self, tenant_id: str) -> Tenant:
        return self._tenants[tenant_id]

    def tenant_metrics(self, tenant_id: str, phase: int) -> dict:
        """The raw per-episode metric arrays of one served tenant phase —
        directly comparable (bit-exact) to the matching
        `run_stream(solo_stream(...))` phase's `metrics[...][lane]`."""
        res, lane = self._tenants[tenant_id].results[phase]
        return {k: v[lane] for k, v in res.metrics.items()}

    def tenant_summary(self, tenant_id: str, phase: int,
                       episode: int | None = None) -> dict:
        res, lane = self._tenants[tenant_id].results[phase]
        return res.episode_summary(lane, episode)

    def stats(self) -> dict:
        """Service-level metrics surface (the BENCH_serving.json record).

        Phase-latency percentiles are computed over *steady-state* ticks
        only (ticks after the last one that compiled anything), weighted by
        the phases each tick served — a tick-1 compile is a one-off cost
        the resident programs amortize away, and folding it into p99 made
        the tail look ~100x worse than the service actually runs.  The
        compile cost is reported separately as `compile_s` (total wall of
        every tick that compiled at least one program)."""
        wall = np.asarray(self._tick_wall, np.float64)
        active = np.asarray(self._tick_active, np.float64)
        compiles = np.asarray(self._tick_compiles, int)
        # steady state: ticks after the last one that compiled anything
        last_c = int(np.max(np.nonzero(compiles)[0])) if compiles.any() else -1
        steady = slice(last_c + 1, None)
        # one latency sample per phase served in a steady-state tick
        lat = np.repeat(wall[steady], active[steady].astype(int))
        ep = self.envelope
        epochs_per_tick = (active * ep.n_epochs * ep.n_episodes
                           if ep is not None else active * 0)
        steady_wall = float(wall[steady].sum())
        health: dict[str, int] = {"healthy": 0, "degraded": 0,
                                  "quarantined": 0}
        for t in self._tenants.values():
            health[t.health] = health.get(t.health, 0) + 1
        return {
            "ticks": self.ticks,
            "n_slots": self.n_slots,
            "n_devices": partition.mesh_desc(self.mesh)["n_devices"],
            "tenants_submitted": len(self._tenants),
            "tenants_done": sum(t.done for t in self._tenants.values()),
            "tenants_removed": sum(t.removed for t in self._tenants.values()),
            "tenants_quarantined": sum(t.quarantined
                                       for t in self._tenants.values()),
            "tenant_health": health,
            "phases_served": self._phases_served,
            "phase_latency_p50_s": (float(np.percentile(lat, 50))
                                    if lat.size else None),
            "phase_latency_p99_s": (float(np.percentile(lat, 99))
                                    if lat.size else None),
            "compile_s": float(wall[compiles > 0].sum()),
            "slot_occupancy": (float((active / self.n_slots).mean())
                               if active.size else 0.0),
            "recompiles_total": int(compiles.sum()),
            "recompiles_after_first_tick": (int(compiles[1:].sum())
                                            if compiles.size else 0),
            "steady_ticks": int(wall[steady].size),
            "steady_epochs_per_sec": (
                float(epochs_per_tick[steady].sum() / steady_wall)
                if steady_wall > 0 and wall[steady].size else None),
            "store": {"tags": len(self.store), "capacity":
                      self.store.capacity, "evictions":
                      self.store.evictions},
            "faults": {
                "injected": (len(self.faults.injected)
                             if self.faults is not None else 0),
                "tick_failures": self._tick_failures,
                "divergences": self._divergences,
                "deadline_misses": self._deadline_misses,
                "retries": self._retries_total,
                "quarantines": self._quarantines,
                "stale_dropped": self._stale_dropped,
                "device_shrinks": self._device_shrinks,
                "validation_rejects": self._validation_rejects,
                "rollbacks": self.store.rollbacks,
                "restore_fallbacks": self.store.restore_fallbacks,
            },
        }
