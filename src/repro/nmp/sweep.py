"""Execute layer of the sweep pipeline: one compiled program per lane group.

`run_grid` is a three-layer pipeline:

  plan      (nmp.plan)      : normalize scenarios into a declarative
                              `GridPlan` — shared padding envelope, lanes
                              grouped by DQN-liveness and cube topology
                              (one program per topology group; the routing
                              tensors are trace-time constants), seeds
                              folded into a per-lane seed axis, lanes
                              cost-ordered for shard packing;
  partition (nmp.partition) : build a 2-D (lanes × seeds) device mesh —
                              shape auto-factored from the plan's padded
                              cell counts (`auto_mesh_shape`) or forced via
                              REPRO_SWEEP_MESH — pad each group to
                              mesh-divisible lane/seed counts and shard both
                              axes (`NamedSharding`); degrades to a plain
                              transfer on one device;
  execute   (this module)   : jit one program per lane group — episode
                              chaining as `lax.scan`, the epoch scan outside
                              the lane vmap, and the folded seed axis as an
                              inner vmap, so S seed replicas of a lane share
                              one copy of its trace arrays and every lane
                              reports mean±std variance bands for free.
                              Groups are *dispatched* heaviest-first
                              (`plan.packed_group_order`) with the next
                              group's host batch built while the previous
                              one runs on device, and the previous group's
                              results fetched/unfolded on a background
                              thread (REPRO_SWEEP_LAND=async, the default)
                              so landings overlap the in-flight device step
                              too.  Warm agent batches are stacked through
                              reusable host staging buffers
                              (REPRO_STORE_STAGING=on, the default; see
                              AgentStaging) instead of per-cell device
                              imports.  Both knobs are bit-identical to
                              their historical paths.

Hot-path layout: the epoch `lax.scan` sits *outside* the (lane, seed) vmaps
(scan-of-vmap, not vmap-of-scan), so the agent invocation inside one epoch is
a genuine scalar `lax.cond` on "any lane invokes" — epochs where every AIMM
lane is between invocations skip the whole DQN machinery at run time (and TOM
candidate scoring is gated the same way on "any lane profiles").  The input
batch is donated to the compiled sweep (`donate_argnames`) and per-epoch
metric timelines are stored at slim dtypes (`valid_t` as uint16).

2-D mesh layout: the env/metric grid inside the program is (L, S, ...) with
L sharded over the mesh's lane axis and S over its seed axis — a (lane,
seed) cell never crosses a device, so per-cell results are bit-identical for
every mesh shape (4x1, 2x2, 1x4, or no mesh at all).  The agent batch stays
*flat* lane-major (L*S, ...): a reshape of a P(lanes, seeds)-sharded (L, S)
array to (L*S,) is exactly GSPMD's dimension-merge P((lanes, seeds))
sharding, so flattening costs no resharding and the whole DQN machinery is
layout-oblivious.  When the executed seed width exceeds 1 the epoch body
hoists the seed-invariant half of the cost model out of the inner seed vmap
(`BodyFlags.share_seed_inv` -> engine.SharedEpoch): window fetches, validity
masks, row-buffer stamp races, PEI thresholds and page-touch counts are
computed once per lane and broadcast across the S replicas.

Agent lifecycle: cold-start lanes are born and die inside the compiled
program (the historical path, bit-identical by construction); lanes that
declare a `Scenario.lineage` tag compile into a separate warm-capable
program whose initial agent batch is an input and whose final agent batch is
an output, threaded through a `continual.PolicyStore` so one DQN can live
across run_grid calls, program switches and process restarts (see
nmp.continual).

Exactness: technique/mapper/forced-action are traced `TraceCtx` selectors and
every engine update is gated on `has_ops` (see engine._epoch_sim/_epoch_apply),
so each (lane, seed) cell's `cycles` / `ops_done` / final OPC are bit-identical
to a serial `run_episode` / `run_program` of the same scenario — whether the
lane axis is sharded over devices or not, and however seeds are folded
(tests/test_sweep_equivalence.py, tests/test_plan_partition.py).
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
import warnings
from concurrent.futures import ThreadPoolExecutor
from functools import partial
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import agent as agent_mod
from repro.nmp import partition
from repro.nmp import plan as plan_mod
from repro.nmp.config import NMPConfig
from repro.nmp.engine import (TraceCtx, _init_env, default_agent_cfg,
                              scan_epochs, state_spec_for)
from repro.nmp.plan import GridPlan, group_flags, needs_agent, plan_grid
from repro.nmp.scenarios import Scenario
from repro.nmp.stats import energy_breakdown, energy_nj, resample_opc

LAND_KNOB = "REPRO_SWEEP_LAND"
LAND_MODES = ("async", "sync")
STAGING_KNOB = "REPRO_STORE_STAGING"
STAGING_MODES = ("on", "off")


def _env_choice(knob: str, default: str, choices: tuple[str, ...]) -> str:
    val = os.environ.get(knob, default)
    if val not in choices:
        raise ValueError(f"{knob}={val!r} is not a valid mode; expected one "
                         f"of {choices}")
    return val


def land_mode() -> str:
    """How `run_grid` lands dispatched group results (REPRO_SWEEP_LAND):
    `async` (default) fetches/unfolds group k on a background thread while
    group k+1 runs on device; `sync` is the historical in-loop landing."""
    return _env_choice(LAND_KNOB, "async", LAND_MODES)


def staging_enabled() -> bool:
    """Whether the warm agent batch is built through reusable host staging
    buffers (REPRO_STORE_STAGING, default on) instead of the historical
    per-cell device stacking.  Both paths are bit-identical."""
    return _env_choice(STAGING_KNOB, "on", STAGING_MODES) == "on"


# Fail fast on typo'd knobs at import, like REPRO_EPOCH_BACKEND.
land_mode()
staging_enabled()


@partial(jax.jit,
         static_argnames=("cfg", "spec", "agent_cfg", "n_epochs", "n_episodes",
                          "ring_len", "flags", "want_agent"),
         donate_argnames=("batch",))
def _run_sweep(batch, tom_cands, cfg, spec, agent_cfg, n_epochs, n_episodes,
               ring_len, flags, warm_agent=None, want_agent=False):
    """Scan over episodes; inside, the batched epoch scan runs every
    (lane, seed) cell in lockstep (nested (lane, seed) vmap of the epoch
    body, scalar any-lane-invokes agent cond).  The env is re-initialized per
    episode while the agent chains through.  `batch["ep_seed"]` is
    (L, S, E); trace arrays stay per-lane (L, ...) and are shared across the
    seed axis.

    Agent lifecycle: by default every (lane, seed) cell cold-starts its DQN
    inside the program (the exact historical path).  Lineage groups pass the
    initial agent batch in as `warm_agent` (flat (L*S,) cells, warm-started
    from a PolicyStore or cold-started on a fresh lineage) and set
    `want_agent` to get the final agent batch back out for the store."""
    trace = {k: batch[k] for k in ("dest", "src1", "src2")}
    L, S, _E = batch["ep_seed"].shape
    base_ctx = TraceCtx(
        n_ops=batch["n_ops"], n_pages=batch["n_pages"],
        t_ring=batch["t_ring"], pei_idx=batch["pei_idx"],
        technique=batch["technique"], mapper=batch["mapper"],
        forced_action=batch["forced_action"],
        explore=jnp.zeros_like(batch["ep_explore"][:, 0]))
    init_envs = jax.vmap(jax.vmap(
        lambda pt, s: _init_env(pt, cfg, spec, s, ring_len),
        in_axes=(None, 0)))                               # (L, S) grid of envs
    if warm_agent is not None:
        agent0 = warm_agent
    else:
        agent0 = (jax.vmap(lambda s: agent_mod.cold_start(s, agent_cfg))(
            batch["ep_seed"][:, :, 0].reshape(L * S))
            if flags.has_agent else None)
    env0 = init_envs(batch["page_table"], batch["ep_seed"][:, :, 0])

    def episode(carry, x):
        agent, _ = carry
        seeds, explore = x                        # (L, S) / (L,)
        ctx = base_ctx._replace(explore=explore)
        env = init_envs(batch["page_table"], seeds)
        env, agent2, ms = scan_epochs(trace, batch["rw"], env, agent,
                                      tom_cands, ctx, cfg, spec, agent_cfg,
                                      n_epochs, flags, seed_axis=True)
        out = {
            "cycles": env.cycles, "ops": env.ops_done,
            "hops_sum": env.hops_sum, "util_sum": env.util_sum,
            "epochs": env.epochs, "migrations": env.mig_count,
            "pages_migrated": env.mig_page_mask.sum(axis=-1),
            "access_total": env.access_total,
            "access_on_migrated": env.access_on_migrated,
            "energy": env.energy,
            # per-epoch timelines, stored slim: ms leaves are (n_epochs, L, S)
            "opc_t": jnp.moveaxis(ms["opc"], 0, -1),
            "valid_t": jnp.moveaxis(ms["valid"].astype(jnp.uint16), 0, -1),
            "invoke_t": jnp.moveaxis(ms["invoke"].astype(jnp.uint16), 0, -1),
        }
        return ((agent2 if flags.has_agent else agent), env), out

    xs = (jnp.moveaxis(batch["ep_seed"], -1, 0),          # (E, L, S)
          batch["ep_explore"].T)                          # (E, L)
    (agent_fin, env_fin), outs = jax.lax.scan(episode, (agent0, env0), xs,
                                              length=n_episodes)
    # outs leaves are (E, L, S, ...); present them cell-major.
    outs = {k: jnp.moveaxis(v, 0, 2) for k, v in outs.items()}
    return outs, env_fin, (agent_fin if want_agent else None)


@dataclasses.dataclass
class SweepResult:
    scenarios: list[Scenario]
    cfg: NMPConfig
    metrics: dict[str, np.ndarray]   # (B, E) scalars; energy (B, E, EN_N);
                                     # opc_t/valid_t/invoke_t (B, E, n_epochs)
    final_env: Any                   # EnvState stacked over the lane axis
    n_episodes: int                  # common (padded) episode count E
    wall_s: float                    # build + compile + run wall time
    plan: GridPlan | None = None     # the executed plan (seed folding, groups)
    n_devices: int = 1               # mesh width the sweep ran on
    mesh_shape: tuple[int, int] = (1, 1)   # (lane, seed) device mesh dims
    store: Any = None                # the PolicyStore holding the grid's
                                     # final agent lineages (None when no
                                     # lane declared a lineage)

    def episode_summary(self, lane: int, episode: int | None = None) -> dict:
        """Per-(lane, episode) summary with the same keys as stats.summarize.

        `episode` defaults to the scenario's last real episode (its greedy
        eval episode when `eval_episode` is set)."""
        sc = self.scenarios[lane]
        e = sc.total_episodes - 1 if episode is None else episode
        m = self.metrics
        cycles = max(float(m["cycles"][lane, e]), 1.0)
        ops = float(m["ops"][lane, e])
        return {
            "cycles": cycles,
            "ops": ops,
            "opc": ops / cycles,
            "mean_hops": float(m["hops_sum"][lane, e]) / max(ops, 1.0),
            "compute_util": (float(m["util_sum"][lane, e])
                             / max(float(m["epochs"][lane, e]), 1.0)),
            "migrations": float(m["migrations"][lane, e]),
            "frac_pages_migrated": (float(m["pages_migrated"][lane, e])
                                    / sc.trace.n_pages),
            "frac_access_migrated": (float(m["access_on_migrated"][lane, e])
                                     / max(float(m["access_total"][lane, e]),
                                           1.0)),
            "energy_nj": energy_nj(m["energy"][lane, e]),
            "energy_breakdown": energy_breakdown(m["energy"][lane, e]),
        }

    def summary(self, lane: int) -> dict:
        return self.episode_summary(lane)

    def opc_timeline(self, lane: int, episode: int | None = None,
                     samples: int = 64) -> np.ndarray:
        sc = self.scenarios[lane]
        e = sc.total_episodes - 1 if episode is None else episode
        return resample_opc(self.metrics["opc_t"][lane, e],
                            self.metrics["valid_t"][lane, e], samples)

    def invocations(self, lane: int, episode: int | None = None) -> int:
        """Agent invocations in one episode (all episodes when None) — the
        paper's natural x-axis for convergence ("invocations to threshold
        OPC", see benchmarks/bench_continual.py)."""
        sc = self.scenarios[lane]
        inv = self.metrics["invoke_t"][lane]
        if episode is not None:
            return int(inv[episode].sum())
        return int(inv[:sc.total_episodes].sum())

    # ---- variance bands over the folded seed axis ----

    def seed_group(self, lane: int) -> list[int]:
        """Scenario indices of every seed replica folded into `lane`'s lane."""
        if self.plan is None:
            return [lane]
        return list(self.plan.seed_group(lane))

    def variance_band(self, lane: int, episode: int | None = None,
                      keys: Sequence[str] = ("opc", "cycles",
                                             "energy_nj")) -> dict:
        """mean±std of per-seed episode summaries across `lane`'s seed group.

        Returns {"seeds": [...], "n": S, "<key>_mean": ..., "<key>_std": ...}
        — the variance-band record every figure gets for free from the folded
        seed axis."""
        members = self.seed_group(lane)
        sums = [self.episode_summary(i, episode) for i in members]
        band: dict[str, Any] = {
            "seeds": [self.scenarios[i].seed for i in members],
            "n": len(members),
        }
        for k in keys:
            vals = np.asarray([s[k] for s in sums], np.float64)
            band[f"{k}_mean"] = float(vals.mean())
            band[f"{k}_std"] = float(vals.std())
        return band

    def opc_timeline_band(self, lane: int, episode: int | None = None,
                          samples: int = 64) -> tuple[np.ndarray, np.ndarray]:
        """(mean, std) resampled OPC timelines across `lane`'s seed group."""
        tls = np.stack([self.opc_timeline(i, episode, samples)
                        for i in self.seed_group(lane)])
        return tls.mean(axis=0), tls.std(axis=0)


class AgentStaging:
    """Reusable host-side staging for the warm agent batch.

    The historical stacking path builds the batch from scratch every tick:
    one host->device import per warm cell, one `cold_start` per fresh cell,
    then an on-device `jnp.stack` per leaf — all garbage one tick later.
    At fleet scale (the serving layer re-stacks every resident slot every
    tick) that is hundreds of small transfers per tick.  This class keeps

      * one preallocated numpy buffer per agent leaf, shaped
        (n_cells, *leaf) — rows are filled in place from the store's host
        snapshots, so a steady-state tick pays ONE device transfer per
        *leaf* (via `partition.shard_agent_batch`) instead of one per cell;
      * a bounded cache of cold-start snapshots keyed by (seed, agent_cfg),
        so a fresh lineage's cold cell is computed once, not every tick.

    Buffers are (re)allocated whenever the cell count or leaf envelope
    changes and reused otherwise; `device_put`/jit copy out of them at
    dispatch, so refilling next tick is safe.  The stacked values are
    bit-identical to the historical path's."""

    _COLD_CACHE_MAX = 128        # cold cells are only needed for *fresh*
                                 # tags, so this never grows in steady state

    def __init__(self):
        self._bufs: list[np.ndarray] | None = None
        self._treedef = None
        self._cold: dict = {}

    def cold_cell(self, seed: int, agent_cfg):
        """Host snapshot of `agent_mod.cold_start(seed, agent_cfg)`."""
        key = (int(seed), agent_cfg)
        if key not in self._cold:
            if len(self._cold) >= self._COLD_CACHE_MAX:
                self._cold.pop(next(iter(self._cold)))
            self._cold[key] = agent_mod.export_agent(
                agent_mod.cold_start(int(seed), agent_cfg))
        return self._cold[key]

    def stack(self, cells):
        """Stack host-side cell pytrees into the reused (n_cells, ...)
        buffers; returns the stacked pytree (numpy leaves)."""
        leaves0, treedef = jax.tree_util.tree_flatten(cells[0])
        fit = (self._bufs is not None and self._treedef == treedef
               and len(self._bufs) == len(leaves0)
               and self._bufs[0].shape[0] == len(cells)
               and all(b.shape[1:] == np.shape(l) and b.dtype == l.dtype
                       for b, l in zip(self._bufs, leaves0)))
        if not fit:
            self._bufs = [np.empty((len(cells),) + np.shape(l),
                                   np.asarray(l).dtype) for l in leaves0]
            self._treedef = treedef
        for i, cell in enumerate(cells):
            for buf, leaf in zip(self._bufs, jax.tree_util.tree_leaves(cell)):
                buf[i] = leaf
        return jax.tree_util.tree_unflatten(treedef, self._bufs)


def _warm_agent_batch(group, n_lanes_padded: int, store, agent_cfg,
                      n_seeds: int | None = None, mesh=None, staging=None):
    """Initial agent batch for a lineage group: flat (L*S,) cells, lane-major.

    A cell whose lineage tag is in the store warm-starts from the stored
    agent (with the scenario-boundary handoff applied); a fresh tag
    cold-starts the lineage with the cell's own seed.  `n_seeds` is the
    *executed* seed width (the group's, padded up to the mesh seed dim by
    repeating seed slot 0 — mirroring `partition.pad_seed_axis`);
    device-divisibility padding lanes repeat lane 0's cells, mirroring
    `partition.pad_group_batch`.  With a mesh the stacked cells are placed
    on the merged (lanes, seeds) sharding up front.

    `staging` is an optional `AgentStaging` whose host buffers persist
    across calls (the serving layer holds one per server); by default a
    throwaway one is used when REPRO_STORE_STAGING is on, and the
    historical per-cell device stacking when it is off.  All paths produce
    bit-identical batches."""
    S = group.n_seeds if n_seeds is None else n_seeds
    if staging is None and staging_enabled():
        staging = AgentStaging()
    cells = []
    for lane in group.lanes:
        tag = lane.scenario.lineage
        # one checkout per tag; seed replicas reuse the read-only cell and
        # the stacking below gives each its own copy
        warm_in_store = store is not None and tag in store
        if staging is not None:
            warm = store.checkout_host(tag) if warm_in_store else None
        else:
            warm = store.checkout(tag) if warm_in_store else None
        seeds = lane.seeds + (lane.seeds[0],) * (S - group.n_seeds)
        for seed in seeds:
            if warm is not None:
                cells.append(warm)
            elif staging is not None:
                cells.append(staging.cold_cell(int(seed), agent_cfg))
            else:
                cells.append(agent_mod.cold_start(int(seed), agent_cfg))
    lane0 = cells[:S]
    for _ in range(n_lanes_padded - group.n_lanes):
        cells.extend(lane0)
    if staging is not None:
        stacked = staging.stack(cells)
    else:
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *cells)
    return partition.shard_agent_batch(stacked, mesh)


def prepare_group_batch(plan: GridPlan, group, group_cfg: NMPConfig, mesh,
                        n_lanes: int | None = None, host_cache=None):
    """Host-side build + device placement of one group's input batch.

    `n_lanes` forces the padded lane count (the serving layer's fixed slot
    programs); by default the group is padded to the smallest
    mesh-divisible lane count, and the folded seed axis to the smallest
    mesh-divisible seed width (`partition.padded_seed_count`; padding slots
    re-simulate seed slot 0 and are dropped).  `host_cache` is threaded to
    `plan.build_group_batch` for per-lane host-array reuse across calls.
    Returns (device batch, padded lane count) — read the executed seed width
    off `batch["ep_seed"].shape[1]` (shape metadata stays readable after the
    batch is donated).  The host->device transfer happens here, so a caller
    can overlap it with a previously dispatched compiled call (double
    buffering)."""
    n_lanes_padded = (partition.padded_lane_count(group.n_lanes, mesh)
                      if n_lanes is None else n_lanes)
    if n_lanes_padded < group.n_lanes:
        raise ValueError(f"n_lanes={n_lanes_padded} < group lane count "
                         f"{group.n_lanes}")
    if n_lanes_padded != partition.padded_lane_count(n_lanes_padded, mesh):
        raise ValueError(f"n_lanes={n_lanes_padded} is not divisible by the "
                         "device mesh width")
    batch_np = plan_mod.build_group_batch(plan, group, group_cfg,
                                          host_cache=host_cache)
    batch_np = partition.pad_seed_axis(
        batch_np, partition.padded_seed_count(group.n_seeds, mesh))
    batch_np = partition.pad_group_batch(batch_np, n_lanes_padded)
    return partition.shard_group_batch(batch_np, mesh), n_lanes_padded


def executed_flags(group, n_seeds: int):
    """The BodyFlags a group actually compiles with for an executed seed
    width of `n_seeds`: mesh seed-padding can widen a width-1 group's seed
    axis, in which case the seed-invariant sharing pays even though the plan
    compiled it out — and a width-1 execution always compiles it out."""
    share = n_seeds > 1 and plan_mod.seed_share_enabled()
    if group.flags.share_seed_inv == share:
        return group.flags
    return group.flags._replace(share_seed_inv=share)


def dispatch_sweep(batch, tom_cands, group_cfg: NMPConfig, spec, agent_cfg,
                   n_epochs: int, n_episodes: int, ring_len: int, flags,
                   warm_agent=None, want_agent: bool = False):
    """Dispatch the compiled sweep for one prepared group batch.

    The call is asynchronous: the returned (outs, final env, final agent)
    leaves are unmaterialized jax arrays — block (`jax.block_until_ready`)
    when the values are needed, and build the *next* batch in between to
    hide its host->device transfer behind the running program."""
    with warnings.catch_warnings():
        # int trace/ctx buffers have no same-shaped outputs to reuse;
        # their donation being unusable is expected, not a leak.
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        return _run_sweep(batch, tom_cands, group_cfg, spec, agent_cfg,
                          n_epochs, n_episodes, ring_len, flags,
                          warm_agent=warm_agent, want_agent=want_agent)


def lane_finite_mask(out: dict, agent_fin, n_lanes: int,
                     n_seeds: int = 1) -> np.ndarray:
    """Per-lane divergence guard: True where every float metric of the lane
    AND every float param leaf of its final agent cells is finite.

    One batched `isfinite` reduction per completed tick, evaluated at host
    sync — never per epoch.  The whole check is ONE jitted program (fused
    reductions; compiles once per resident shape set, cached separately from
    the sweep programs), so the steady-state cost is a single tiny device
    call over already-materialized outputs.  `out` leaves are
    (L_padded, S, ...) metric arrays; `agent_fin` leaves (when given) are
    flat (L_padded*S, ...) cells.  Only the first `n_lanes` lanes are
    reported (padding lanes repeat lane 0 and are dropped by callers)."""
    lanes_padded = None
    floats = []
    for v in out.values():
        if jnp.issubdtype(v.dtype, jnp.floating):
            floats.append(v)
            lanes_padded = v.shape[0]
    if agent_fin is not None:
        for leaf in jax.tree.leaves(agent_fin.params):
            if jnp.issubdtype(leaf.dtype, jnp.floating):
                floats.append(leaf)
                if lanes_padded is None:
                    lanes_padded = leaf.shape[0] // n_seeds
    if not floats:
        return np.ones(n_lanes, bool)
    return np.asarray(_finite_mask_prog(floats, lanes_padded))[:n_lanes]


@partial(jax.jit, static_argnames=("lanes_padded",))
def _finite_mask_prog(floats, lanes_padded: int):
    # every leaf is lane-major: (L_padded, S, ...) metrics and (L_padded*S,
    # ...) agent cells both collapse to (lanes_padded, -1)
    ok = jnp.ones((lanes_padded,), bool)
    for v in floats:
        ok = ok & jnp.isfinite(v).reshape(lanes_padded, -1).all(axis=1)
    return ok


def compiled_sweep_programs() -> int:
    """Number of distinct compiled sweep programs resident in the jit cache.

    The serving layer's steady-state guarantee is that this stays constant
    across service ticks once the slot programs are warm."""
    try:
        return int(_run_sweep._cache_size())
    except AttributeError:                     # pragma: no cover - jax API
        return 0


def run_grid(scenarios: Sequence[Scenario], cfg: NMPConfig = NMPConfig(),
             agent_cfg=None, store=None) -> SweepResult:
    """Run every scenario cell of a grid through the plan -> partition ->
    execute pipeline: one batched, jitted program per lane group, the folded
    seed axis vmapped inside each lane, the lane axis sharded over the device
    mesh when more than one device is visible.

    `store` is a `continual.PolicyStore` carrying agent lineages across
    run_grid calls: lanes whose `Scenario.lineage` tag it holds warm-start
    from the stored agent, fresh tags cold-start, and every tag's final
    agent is written back (the store is updated in place and also returned
    as `SweepResult.store`).  With no lineage lanes the store is untouched
    and the compiled programs are exactly the historical cold-start ones.

    Returns a SweepResult whose per-cell `cycles`/`ops`/`opc` match the serial
    `run_episode`/`run_program` protocol bit-for-bit (see module docstring).
    """
    scenarios = list(scenarios)
    t0 = time.time()
    plan = plan_grid(scenarios, cfg)
    spec = state_spec_for(cfg)
    agent_cfg = agent_cfg or default_agent_cfg(cfg)
    devices = partition.sweep_devices()
    shape = (partition.sweep_mesh_shape(len(devices))
             or partition.auto_mesh_shape(
                 len(devices), [(g.n_lanes, g.n_seeds, g.n_episodes)
                                for g in plan.groups]))
    mesh = partition.build_mesh(devices, shape)
    tom_cands = partition.replicate(plan_mod.plan_tom_candidates(plan, cfg),
                                    mesh)
    if store is None and plan.lineage_tags():
        from repro.nmp.continual import PolicyStore
        store = PolicyStore()

    # Mixed-topology grids: the stacked final env needs one link-space
    # width, so per-group pending link loads are padded to the widest
    # topology's link count before stacking (padding links carry zero load).
    from repro.nmp.topology import get_topology
    n_links_max = max(
        get_topology(dataclasses.replace(cfg, topology=t)).n_links
        for t in dict.fromkeys(plan.topologies))

    outs: list = [None] * len(scenarios)
    envs: list = [None] * len(scenarios)
    staging = AgentStaging() if staging_enabled() else None
    # The store is touched from two threads under async landing: warm
    # checkouts in launch() (main thread) vs lineage write-backs in land()
    # (worker).  A tag never spans groups, so there is no semantic race —
    # the lock only keeps the registry's dict/LRU bookkeeping atomic.
    store_lock = threading.Lock()

    def launch(group):
        """Host batch build + async dispatch of one group's program."""
        group_cfg = dataclasses.replace(cfg, topology=group.topology)
        batch, n_lanes_padded = prepare_group_batch(plan, group, group_cfg,
                                                    mesh)
        s_pad = int(batch["ep_seed"].shape[1])
        if group.lineage:
            with store_lock:
                warm = _warm_agent_batch(group, n_lanes_padded, store,
                                         agent_cfg, n_seeds=s_pad, mesh=mesh,
                                         staging=staging)
        else:
            warm = None
        out, env_fin, agent_fin = dispatch_sweep(
            batch, tom_cands, group_cfg, spec, agent_cfg, plan.n_epochs,
            group.n_episodes, plan.ring_len, executed_flags(group, s_pad),
            warm_agent=warm, want_agent=group.lineage)
        return group, group_cfg, s_pad, out, env_fin, agent_fin

    def land(state):
        """Block on a dispatched group, fetch to host, unfold its lanes."""
        group, group_cfg, s_pad, out, env_fin, agent_fin = state
        out = partition.host_fetch(jax.block_until_ready(out))
        env_fin = partition.host_fetch(env_fin)
        pad_l = n_links_max - get_topology(group_cfg).n_links
        if pad_l:
            env_fin = env_fin._replace(pending_mig_loads=np.pad(
                env_fin.pending_mig_loads, [(0, 0)] * 2 + [(0, pad_l)]))
        pad_e = plan.n_episodes - group.n_episodes
        for li, lane in enumerate(group.lanes):
            cells = {}               # seed slot -> unfolded metric dict
            for i, si in zip(lane.indices, lane.slots):
                if si not in cells:
                    cells[si] = (
                        {k: np.pad(np.asarray(v[li, si]),
                                   [(0, pad_e)] + [(0, 0)]
                                   * (v[li, si].ndim - 1))
                         for k, v in out.items()},
                        jax.tree.map(
                            lambda a, li=li, si=si: np.asarray(a[li, si]),
                            env_fin))
                outs[i], envs[i] = cells[si]
        if group.lineage:
            # Hand every tag's final agent back to the store.  When several
            # cells share a tag (seed replicas, repeated tags), the lineage
            # continues from the first cell of the last lane declaring it.
            agent_fin = partition.host_fetch(agent_fin)
            with store_lock:
                for li, lane in enumerate(group.lanes):
                    cell = jax.tree.map(
                        lambda a, li=li, s=lane.slots[0]:
                            np.asarray(a[li * s_pad + s]),
                        agent_fin)
                    store.put(lane.scenario.lineage, cell,
                              scenario=lane.scenario.name)

    # Heaviest group first; one group in flight while the next group's host
    # batch is built, and — under async landing (REPRO_SWEEP_LAND, the
    # default) — the *previous* group's results fetched and unfolded on a
    # background thread while the in-flight group runs on device, so the
    # result drain never sits between one dispatch and the next build.
    # One worker + submission order keeps landings (and store write-backs)
    # in dispatch order; lanes are unfolded into `outs`/`envs` by scenario
    # index, so `SweepResult` ordering is identical either way.  (A tag
    # never spans groups, so warm checkouts in launch() can't race the
    # lineage write-back in land().)
    pool = (ThreadPoolExecutor(max_workers=1, thread_name_prefix="sweep-land")
            if land_mode() == "async" else None)
    try:
        landings = []
        pending = None
        for gi in plan_mod.packed_group_order(plan,
                                              partition.mesh_lane_dim(mesh),
                                              partition.mesh_seed_dim(mesh)):
            launched = launch(plan.groups[gi])
            if pending is not None:
                if pool is not None:
                    landings.append(pool.submit(land, pending))
                else:
                    land(pending)
            pending = launched
        if pending is not None:
            if pool is not None:
                landings.append(pool.submit(land, pending))
            else:
                land(pending)
        for fut in landings:
            fut.result()             # join in order; exceptions propagate
    finally:
        if pool is not None:
            pool.shutdown(wait=True)

    metrics = {k: np.stack([o[k] for o in outs]) for k in outs[0]}
    final_env = jax.tree.map(lambda *xs: np.stack(xs), *envs)
    desc = partition.mesh_desc(mesh)
    return SweepResult(scenarios=scenarios, cfg=cfg, metrics=metrics,
                       final_env=final_env, n_episodes=plan.n_episodes,
                       wall_s=time.time() - t0, plan=plan,
                       n_devices=desc["n_devices"],
                       mesh_shape=tuple(desc["shape"]),
                       store=store)


def run_grid_serial(scenarios: Sequence[Scenario],
                    cfg: NMPConfig = NMPConfig()) -> list[dict]:
    """Reference serial loop over the same grid (one run_episode/run_program
    per lane). Used by the equivalence tests and the benchmark comparison."""
    from repro.nmp.engine import run_episode, run_program
    from repro.nmp.stats import summarize
    out = []
    for sc in scenarios:
        sc_cfg = (dataclasses.replace(cfg, topology=sc.topology)
                  if sc.topology is not None else cfg)
        if needs_agent(sc):
            results = run_program(sc.trace, sc_cfg, sc.technique, "aimm",
                                  episodes=sc.episodes, seed=sc.seed,
                                  page_table=sc.page_table)
            if sc.eval_episode:
                results.append(run_episode(
                    sc.trace, sc_cfg, sc.technique, "aimm",
                    agent=results[-1].agent, seed=sc.seed, explore=False,
                    page_table=sc.page_table))
            out.append(summarize(results[-1]))
        else:
            res = run_episode(sc.trace, sc_cfg, sc.technique, sc.mapper,
                              seed=sc.seed, page_table=sc.page_table,
                              forced_action=sc.forced_action)
            out.append(summarize(res))
    return out
