"""Batched scenario-sweep engine: a whole experiment grid in one compile.

`run_grid` takes a list of `scenarios.Scenario` lanes, pads every trace to a
common (n_ops, n_pages) envelope, stacks per-lane `EnvState`s, and runs the
shared epoch body (`engine._epoch_batched`) `jax.vmap`ed over the scenario
axis.  Episode chaining — the paper's continual-learning protocol where the
DQN persists across episode resets — is a `jax.lax.scan` over episodes inside
the same program, so an app x technique x mapper x seed grid that used to
cost one XLA compile and one Python dispatch per (cell, episode) now costs
one compile per lane group and a single device dispatch.

Hot-path layout: the epoch `lax.scan` sits *outside* the lane vmap
(scan-of-vmap, not vmap-of-scan), so the agent invocation inside one epoch is
a genuine scalar `lax.cond` on "any lane invokes" — epochs where every AIMM
lane is between invocations skip the whole DQN machinery at run time.  The
input batch is donated to the compiled sweep (`donate_argnames`) and the
per-epoch metric timelines are stored at slim dtypes (`valid_t` as uint16),
which cuts the stacked-grid memory high-water mark.

Exactness: technique/mapper/forced-action are traced `TraceCtx` selectors and
every engine update is gated on `has_ops` (see engine._epoch_sim/_epoch_apply),
so each lane's `cycles` / `ops_done` / final OPC are bit-identical to a serial
`run_episode` / `run_program` of the same scenario, including lanes whose
traces are shorter than the batch envelope (tests/test_sweep_equivalence.py).

Lanes are grouped by whether they carry a live DQN (`mapper == "aimm"` with a
learned policy); within a group, `engine.BodyFlags` records which features
(AIMM actions, TOM scoring, PEI thresholding) any lane uses so unused
machinery is compiled out.  A mixed grid compiles at most two programs.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from functools import partial
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import agent as agent_mod
from repro.nmp import baselines
from repro.nmp.config import NMPConfig
from repro.nmp.engine import (EN_N, BodyFlags, TraceCtx, _init_env,
                              default_agent_cfg, make_ctx, pad_trace_ops,
                              pei_top_k, phase_ring_len, scan_epochs,
                              serial_epochs, state_spec_for)
from repro.nmp.paging import default_alloc
from repro.nmp.scenarios import Scenario
from repro.nmp.stats import energy_breakdown, energy_nj, resample_opc


@partial(jax.jit,
         static_argnames=("cfg", "spec", "agent_cfg", "n_epochs", "n_episodes",
                          "ring_len", "flags"),
         donate_argnames=("batch",))
def _run_sweep(batch, tom_cands, cfg, spec, agent_cfg, n_epochs, n_episodes,
               ring_len, flags):
    """Scan over episodes; inside, the batched epoch scan runs every lane in
    lockstep (vmapped epoch body, scalar any-lane-invokes agent cond).  The
    env is re-initialized per episode while the agent chains through."""
    trace = {k: batch[k] for k in ("dest", "src1", "src2")}
    base_ctx = TraceCtx(
        n_ops=batch["n_ops"], n_pages=batch["n_pages"],
        t_ring=batch["t_ring"], pei_idx=batch["pei_idx"],
        technique=batch["technique"], mapper=batch["mapper"],
        forced_action=batch["forced_action"],
        explore=jnp.zeros_like(batch["ep_explore"][:, 0]))
    init_envs = jax.vmap(
        lambda pt, s: _init_env(pt, cfg, spec, s, ring_len))
    agent0 = (jax.vmap(lambda s: agent_mod.init_agent(
        jax.random.PRNGKey(s + 1), agent_cfg))(batch["ep_seed"][:, 0])
        if flags.has_agent else None)
    env0 = init_envs(batch["page_table"], batch["ep_seed"][:, 0])

    def episode(carry, x):
        agent, _ = carry
        seeds, explore = x                        # (B,) each
        ctx = base_ctx._replace(explore=explore)
        env = init_envs(batch["page_table"], seeds)
        env, agent2, ms = scan_epochs(trace, batch["rw"], env, agent,
                                      tom_cands, ctx, cfg, spec, agent_cfg,
                                      n_epochs, flags)
        out = {
            "cycles": env.cycles, "ops": env.ops_done,
            "hops_sum": env.hops_sum, "util_sum": env.util_sum,
            "epochs": env.epochs, "migrations": env.mig_count,
            "pages_migrated": env.mig_page_mask.sum(axis=-1),
            "access_total": env.access_total,
            "access_on_migrated": env.access_on_migrated,
            "energy": env.energy,
            # per-epoch timelines, stored slim: ms leaves are (n_epochs, B)
            "opc_t": ms["opc"].T,
            "valid_t": ms["valid"].astype(jnp.uint16).T,
        }
        return ((agent2 if flags.has_agent else agent), env), out

    xs = (batch["ep_seed"].T, batch["ep_explore"].T)   # (E, B)
    (agent_fin, env_fin), outs = jax.lax.scan(episode, (agent0, env0), xs,
                                              length=n_episodes)
    # outs leaves are (E, B, ...); present them lane-major like the metrics.
    outs = {k: jnp.moveaxis(v, 0, 1) for k, v in outs.items()}
    return outs, env_fin


@dataclasses.dataclass
class SweepResult:
    scenarios: list[Scenario]
    cfg: NMPConfig
    metrics: dict[str, np.ndarray]   # (B, E) scalars; energy (B, E, EN_N);
                                     # opc_t/valid_t (B, E, n_epochs)
    final_env: Any                   # EnvState stacked over the lane axis
    n_episodes: int                  # common (padded) episode count E
    wall_s: float                    # build + compile + run wall time

    def episode_summary(self, lane: int, episode: int | None = None) -> dict:
        """Per-(lane, episode) summary with the same keys as stats.summarize.

        `episode` defaults to the scenario's last real episode (its greedy
        eval episode when `eval_episode` is set)."""
        sc = self.scenarios[lane]
        e = sc.total_episodes - 1 if episode is None else episode
        m = self.metrics
        cycles = max(float(m["cycles"][lane, e]), 1.0)
        ops = float(m["ops"][lane, e])
        return {
            "cycles": cycles,
            "ops": ops,
            "opc": ops / cycles,
            "mean_hops": float(m["hops_sum"][lane, e]) / max(ops, 1.0),
            "compute_util": (float(m["util_sum"][lane, e])
                             / max(float(m["epochs"][lane, e]), 1.0)),
            "migrations": float(m["migrations"][lane, e]),
            "frac_pages_migrated": (float(m["pages_migrated"][lane, e])
                                    / sc.trace.n_pages),
            "frac_access_migrated": (float(m["access_on_migrated"][lane, e])
                                     / max(float(m["access_total"][lane, e]),
                                           1.0)),
            "energy_nj": energy_nj(m["energy"][lane, e]),
            "energy_breakdown": energy_breakdown(m["energy"][lane, e]),
        }

    def summary(self, lane: int) -> dict:
        return self.episode_summary(lane)

    def opc_timeline(self, lane: int, episode: int | None = None,
                     samples: int = 64) -> np.ndarray:
        sc = self.scenarios[lane]
        e = sc.total_episodes - 1 if episode is None else episode
        return resample_opc(self.metrics["opc_t"][lane, e],
                            self.metrics["valid_t"][lane, e], samples)


def _episode_schedule(sc: Scenario, n_episodes: int) -> tuple[np.ndarray, np.ndarray]:
    """(seeds, explore) per episode, padded to the batch episode count.

    Training episodes use seed, seed+1, ... (the run_program protocol); the
    optional eval episode replays the base seed with exploration off. Padding
    episodes continue the seed sequence and are simply not reported."""
    seeds = [sc.seed + e for e in range(sc.episodes)]
    explore = [True] * sc.episodes
    if sc.eval_episode:
        seeds.append(sc.seed)
        explore.append(False)
    while len(seeds) < n_episodes:
        seeds.append(sc.seed + len(seeds))
        explore.append(True)
    return (np.asarray(seeds, np.int32), np.asarray(explore, bool))


def _build_batch(scenarios: Sequence[Scenario], cfg: NMPConfig,
                 n_ops_max: int, n_pages_max: int, n_episodes: int) -> dict:
    lanes = []
    for sc in scenarios:
        tr = sc.trace
        ops = {k: np.asarray(v) for k, v in
               pad_trace_ops(tr, n_ops_max, cfg).items()}
        pt = (np.asarray(sc.page_table, np.int32) if sc.page_table is not None
              else default_alloc(tr.n_pages, cfg))
        # pad the page table/RW flags with never-referenced filler pages that
        # follow the default interleave, so every entry is a legal cube id
        pad_pages = np.arange(tr.n_pages, n_pages_max) % cfg.n_cubes
        pt = np.concatenate([pt, pad_pages.astype(np.int32)])
        rw = np.concatenate([tr.read_write,
                             np.zeros(n_pages_max - tr.n_pages, bool)])
        ctx = make_ctx(tr, cfg, sc.technique, sc.mapper, sc.forced_action)
        seeds, explore = _episode_schedule(sc, n_episodes)
        lanes.append({
            **ops, "page_table": pt, "rw": rw,
            "n_ops": np.int32(ctx.n_ops), "n_pages": np.int32(ctx.n_pages),
            "t_ring": np.int32(ctx.t_ring), "pei_idx": np.int32(ctx.pei_idx),
            "technique": np.int32(ctx.technique),
            "mapper": np.int32(ctx.mapper),
            "forced_action": np.int32(ctx.forced_action),
            "ep_seed": seeds, "ep_explore": explore,
        })
    return {k: jnp.asarray(np.stack([ln[k] for ln in lanes]))
            for k in lanes[0]}


def needs_agent(sc: Scenario) -> bool:
    return sc.mapper == "aimm" and sc.forced_action < 0


def group_flags(scenarios: Sequence[Scenario], cfg: NMPConfig,
                has_agent: bool) -> BodyFlags:
    """Static body flags for one sweep group: the OR over its lanes' needs."""
    pei_k = max((pei_top_k(sc.trace.n_pages, cfg) for sc in scenarios
                 if sc.technique == "pei"), default=0)
    return BodyFlags(
        has_agent=has_agent,
        any_aimm=any(sc.mapper == "aimm" for sc in scenarios),
        any_tom=any(sc.mapper == "tom" for sc in scenarios),
        pei_k=pei_k,
    )


def run_grid(scenarios: Sequence[Scenario], cfg: NMPConfig = NMPConfig(),
             agent_cfg=None) -> SweepResult:
    """Run every scenario lane of a grid as one batched, jitted program.

    Returns a SweepResult whose per-lane `cycles`/`ops`/`opc` match the serial
    `run_episode`/`run_program` protocol bit-for-bit (see module docstring).
    """
    scenarios = list(scenarios)
    assert scenarios, "empty scenario grid"
    t0 = time.time()
    spec = state_spec_for(cfg)
    agent_cfg = agent_cfg or default_agent_cfg(cfg)

    # The spatial envelope (ops/pages/epochs/ring) is shared across both
    # agent-mode groups so the merged final_env and per-epoch timelines stack;
    # the episode count is padded per group — deterministic lanes must not
    # simulate the AIMM lanes' longer training schedules.
    n_ops_max = max(sc.trace.n_ops for sc in scenarios)
    n_pages_max = max(sc.trace.n_pages for sc in scenarios)
    n_epochs = max(serial_epochs(sc.trace.n_ops, cfg) for sc in scenarios)
    ring_len = max(phase_ring_len(sc.trace, cfg) for sc in scenarios)
    n_episodes = max(sc.total_episodes for sc in scenarios)
    tom_cands = baselines.tom_candidates(n_pages_max, cfg)

    groups = [[i for i, sc in enumerate(scenarios) if needs_agent(sc)],
              [i for i, sc in enumerate(scenarios) if not needs_agent(sc)]]
    outs: list = [None] * len(scenarios)
    envs: list = [None] * len(scenarios)
    for has_agent, idxs in zip((True, False), groups):
        if not idxs:
            continue
        group = [scenarios[i] for i in idxs]
        flags = group_flags(group, cfg, has_agent)
        ep_group = max(sc.total_episodes for sc in group)
        batch = _build_batch(group, cfg, n_ops_max, n_pages_max, ep_group)
        with warnings.catch_warnings():
            # int trace/ctx buffers have no same-shaped outputs to reuse;
            # their donation being unusable is expected, not a leak.
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            out, env_fin = _run_sweep(batch, tom_cands, cfg, spec, agent_cfg,
                                      n_epochs, ep_group, ring_len, flags)
        out = jax.block_until_ready(out)
        pad_e = n_episodes - ep_group
        for j, i in enumerate(idxs):
            outs[i] = {k: np.pad(np.asarray(v[j]),
                                 [(0, pad_e)] + [(0, 0)] * (v[j].ndim - 1))
                       for k, v in out.items()}
            envs[i] = jax.tree.map(lambda a, j=j: np.asarray(a[j]), env_fin)

    metrics = {k: np.stack([o[k] for o in outs]) for k in outs[0]}
    final_env = jax.tree.map(lambda *xs: np.stack(xs), *envs)
    return SweepResult(scenarios=scenarios, cfg=cfg, metrics=metrics,
                       final_env=final_env, n_episodes=n_episodes,
                       wall_s=time.time() - t0)


def run_grid_serial(scenarios: Sequence[Scenario],
                    cfg: NMPConfig = NMPConfig()) -> list[dict]:
    """Reference serial loop over the same grid (one run_episode/run_program
    per lane). Used by the equivalence tests and the benchmark comparison."""
    from repro.nmp.engine import run_episode, run_program
    from repro.nmp.stats import summarize
    out = []
    for sc in scenarios:
        if needs_agent(sc):
            results = run_program(sc.trace, cfg, sc.technique, "aimm",
                                  episodes=sc.episodes, seed=sc.seed,
                                  page_table=sc.page_table)
            if sc.eval_episode:
                results.append(run_episode(
                    sc.trace, cfg, sc.technique, "aimm",
                    agent=results[-1].agent, seed=sc.seed, explore=False,
                    page_table=sc.page_table))
            out.append(summarize(results[-1]))
        else:
            res = run_episode(sc.trace, cfg, sc.technique, sc.mapper,
                              seed=sc.seed, page_table=sc.page_table,
                              forced_action=sc.forced_action)
            out.append(summarize(res))
    return out
