"""Deterministic fault-injection harness for the serving / continual stack.

The ROADMAP's north star is a long-lived mapping service; PR 6's
`MappingServer` assumed a perfect world.  This module is the *test harness*
half of the robustness layer: a seeded `FaultPlan` that injects the fault
classes the serving layer must survive, at explicit hook points in
`serving.MappingServer` and `continual.run_stream` — with zero overhead when
no plan is armed (every hook site is guarded by a plain `is not None` check).

Fault classes (`FaultEvent.kind`):

  poison_agent       NaN-fill the float param leaves of a lineage's warm
                     agent (serving: the warm batch cell at dispatch;
                     run_stream: the stored PolicyStore snapshot) — the
                     input the per-tick divergence guard must catch.
  poison_trace       corrupt a tenant trace (NaN/Inf for float arrays,
                     negative page ids otherwise) — the input the
                     `submit()` boundary validation must reject.
  fail_tick          raise `InjectedFault` at dispatch (a crashed service
                     tick), optionally attributed to one tenant.
  stall_tick         sleep `stall_s` on the host at dispatch — exceeds the
                     server's per-phase deadline and is attributed to the
                     stalling tenant.
  corrupt_checkpoint flip bytes of the newest on-disk checkpoint step
                     (meta or shard file) — what the crash-safe
                     `CheckpointManager.restore` must detect and fall back
                     from.
  shrink_devices     shrink the server's visible device count to
                     `keep_devices` — the resident programs re-place (one
                     recompile) and per-lane results must stay bit-identical.

Events are **one-shot** and fire deterministically: serving events fire at
dispatch-attempt ordinal `at` (retries advance the ordinal, so consecutive
events exercise bounded-retry escalation), stream events at phase ordinal
`at`, checkpoint events at save ordinal `at`.  Byte positions for disk
corruption come from the plan's seeded generator, so a corruption run is
reproducible from `(seed, events)` alone.
"""
from __future__ import annotations

import dataclasses
import os
import time
import zipfile
from typing import Sequence

import numpy as np

KINDS = ("poison_agent", "poison_trace", "fail_tick", "stall_tick",
         "corrupt_checkpoint", "shrink_devices")


class InjectedFault(RuntimeError):
    """An injected tick/phase failure.  `tenant` attributes the fault to one
    tenant/lineage (None = whole-tick fault); the serving layer uses it to
    degrade only the affected tenant."""

    def __init__(self, msg: str, tenant: str | None = None,
                 kind: str = "fail_tick"):
        super().__init__(msg)
        self.tenant = tenant
        self.kind = kind


@dataclasses.dataclass
class FaultEvent:
    """One armed fault (see module docstring for the `kind` taxonomy)."""
    kind: str
    at: int = 0                      # dispatch-attempt / phase / save ordinal
    tenant: str | None = None        # target tenant or lineage tag
    stall_s: float = 0.2             # stall_tick host sleep
    n_bytes: int = 16                # corrupt_checkpoint bytes to flip
    target: str = "shard"            # corrupt_checkpoint: "shard" | "meta"
    step: int | None = None          # corrupt_checkpoint step (None = newest)
    keep_devices: int = 1            # shrink_devices survivor count
    fired: bool = False

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {KINDS})")


class FaultPlan:
    """A seeded, deterministic schedule of `FaultEvent`s.

    Pass one to `MappingServer(faults=...)` or `run_stream(faults=...)`; the
    hook methods below are called from the explicit injection points and do
    nothing (cheaply) when no unfired event matches.  `injected` logs every
    fired event as `(kind, at, tenant)` for test/bench assertions."""

    def __init__(self, events: Sequence[FaultEvent] = (), seed: int = 0):
        self.events = list(events)
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.injected: list[tuple[str, int, str | None]] = []
        self._saves = 0                  # checkpoint-save ordinal counter

    def arm(self, event: FaultEvent) -> "FaultPlan":
        self.events.append(event)
        return self

    def _take(self, kind: str, at: int,
              tenants: Sequence[str] | None = None) -> list[FaultEvent]:
        """Fire (and mark) every unfired `kind` event scheduled at `at` whose
        target tenant is unrestricted or present in `tenants`."""
        out = []
        for ev in self.events:
            if ev.fired or ev.kind != kind or ev.at != at:
                continue
            if (tenants is not None and ev.tenant is not None
                    and ev.tenant not in tenants):
                continue
            ev.fired = True
            self.injected.append((ev.kind, at, ev.tenant))
            out.append(ev)
        return out

    # -- serving hooks --------------------------------------------------

    def on_dispatch(self, attempt: int,
                    tenants: Sequence[str]) -> tuple[str, ...]:
        """Called by `MappingServer` once per dispatch attempt.  Sleeps for
        stall events, raises `InjectedFault` for fail events, and returns the
        ids of tenants whose lane was stalled (deadline attribution)."""
        stalled = []
        for ev in self._take("stall_tick", attempt, tenants):
            time.sleep(ev.stall_s)
            stalled.append(ev.tenant)
        for ev in self._take("fail_tick", attempt, tenants):
            raise InjectedFault(
                f"injected tick failure at dispatch attempt {attempt}"
                + (f" (tenant {ev.tenant!r})" if ev.tenant else ""),
                tenant=ev.tenant)
        return tuple(t for t in stalled if t is not None)

    def poison_warm_agents(self, attempt: int, tenants: Sequence[str],
                           warm, n_seeds: int = 1):
        """NaN-fill the float param leaves of matching tenants' warm-agent
        cells (flat (L*S, ...) stacked batch) at dispatch."""
        import jax
        import jax.numpy as jnp
        lanes = [li for ev in self._take("poison_agent", attempt, tenants)
                 for li, t in enumerate(tenants) if t == ev.tenant
                 or ev.tenant is None]
        if not lanes or warm is None:
            return warm
        cells = jnp.asarray([li * n_seeds + s for li in sorted(set(lanes))
                             for s in range(n_seeds)])

        def nan_fill(leaf):
            if not jnp.issubdtype(leaf.dtype, jnp.floating):
                return leaf
            # staging-buffer batches arrive as host numpy; never scribble
            # NaNs into a reused staging buffer in place
            return jnp.asarray(leaf).at[cells].set(jnp.nan)

        return warm._replace(params=jax.tree.map(nan_fill, warm.params))

    def shrink_devices_now(self, attempt: int) -> int | None:
        """Device count the server must shrink to at this attempt (None =
        no shrink armed)."""
        evs = self._take("shrink_devices", attempt)
        return evs[-1].keep_devices if evs else None

    # -- stream hooks ---------------------------------------------------

    def on_phase(self, phase: int, store) -> None:
        """Called by `run_stream` before each phase: poison stored lineage
        snapshots, stall, or fail the phase."""
        for ev in self._take("poison_agent", phase,
                             tenants=tuple(store.tags)):
            tags = [ev.tenant] if ev.tenant is not None else store.tags
            for tag in tags:
                if tag in store:
                    poison_store_agent(store, tag)
        for ev in self._take("stall_tick", phase):
            time.sleep(ev.stall_s)
        for ev in self._take("fail_tick", phase):
            raise InjectedFault(
                f"injected stream failure at phase {phase}"
                + (f" (lineage {ev.tenant!r})" if ev.tenant else ""),
                tenant=ev.tenant)

    def on_checkpoint(self, directory: str) -> None:
        """Called after each checkpoint save; corrupt events armed at this
        save ordinal flip bytes of the just-written (or `step`-named) step."""
        save = self._saves
        self._saves += 1
        for ev in self._take("corrupt_checkpoint", save):
            self.corrupt_checkpoint(directory, step=ev.step,
                                    target=ev.target, n_bytes=ev.n_bytes)

    # -- disk corruption utilities --------------------------------------

    def corrupt_checkpoint(self, directory: str, step: int | None = None,
                           target: str = "shard", n_bytes: int = 16,
                           host_id: int = 0) -> str:
        """Flip `n_bytes` seeded byte positions of one file of a committed
        checkpoint step (the newest when `step` is None).  Returns the path
        corrupted.  Deterministic given the plan's seed."""
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                       if d.startswith("step_") and not d.endswith(".tmp")
                       and d.split("_")[1].isdigit())
        if not steps:
            raise FileNotFoundError(f"no committed steps in {directory}")
        step = steps[-1] if step is None else step
        name = "meta.json" if target == "meta" else f"shard_{host_id}.npz"
        path = os.path.join(directory, f"step_{step:09d}", name)
        corrupt_bytes(path, self.rng, n_bytes=n_bytes)
        self.injected.append(("corrupt_checkpoint", step, name))
        return path


def corrupt_bytes(path: str, rng: np.random.Generator,
                  n_bytes: int = 16) -> None:
    """XOR-flip `n_bytes` positions of `path` in place (positions/masks from
    `rng`, so a seeded generator makes the corruption reproducible)."""
    size = os.path.getsize(path)
    if size == 0:
        raise ValueError(f"cannot corrupt empty file {path}")
    pos = rng.integers(0, size, size=min(n_bytes, size))
    masks = rng.integers(1, 256, size=pos.size)
    with open(path, "r+b") as f:
        data = bytearray(f.read())
        for p, m in zip(pos, masks):
            data[int(p)] ^= int(m)
        f.seek(0)
        f.write(bytes(data))
        f.flush()
        os.fsync(f.fileno())


def tamper_leaf(directory: str, step: int, key: str, host_id: int = 0) -> None:
    """Silently corrupt ONE leaf of a committed checkpoint: rewrite the shard
    npz with that leaf's bytes bit-flipped, keeping the zip container valid.
    The file parses fine — only the per-array checksum recorded in the
    checkpoint meta can catch it (the `CheckpointManager` restore guard)."""
    path = os.path.join(directory, f"step_{step:09d}", f"shard_{host_id}.npz")
    with np.load(path) as data:
        arrays = {k: np.array(data[k]) for k in data.files}
    if key not in arrays:
        raise KeyError(f"{key!r} not in {sorted(arrays)}")
    a = arrays[key]
    raw = bytearray(a.tobytes())
    raw[0] ^= 0xFF
    arrays[key] = np.frombuffer(bytes(raw), a.dtype).reshape(a.shape)
    np.savez(path, **arrays)


def poison_store_agent(store, tag: str) -> None:
    """NaN-fill the float param leaves of a PolicyStore lineage's stored
    snapshot in place (bypassing `put`, so the store's version bookkeeping
    does not advance — this simulates silent corruption, not a bad put)."""
    import jax
    snap = store.get(tag)
    poisoned = snap._replace(params=jax.tree.map(
        lambda a: (np.full_like(a, np.nan)
                   if np.issubdtype(a.dtype, np.floating) else a),
        snap.params))
    store._agents[tag] = poisoned


def poison_trace(trace, mode: str = "negative"):
    """A corrupted copy of a Trace: `negative` writes invalid negative page
    ids into `dest`; `nan` converts `dest` to float and NaN-poisons it.  Both
    must be rejected at the `MappingServer.submit()` boundary."""
    import dataclasses as dc
    if mode == "negative":
        dest = np.array(trace.dest, np.int32)
        dest[:: max(len(dest) // 7, 1)] = -3
    elif mode == "nan":
        dest = np.array(trace.dest, np.float64)
        dest[:: max(len(dest) // 7, 1)] = np.nan
    else:
        raise ValueError(f"unknown poison mode {mode!r}")
    return dc.replace(trace, dest=dest)


def params_finite(snapshot) -> bool:
    """Host-side check that every float param leaf of an agent snapshot is
    finite (the serving layer's stored-snapshot triage before rollback)."""
    import jax
    return all(np.isfinite(leaf).all()
               for leaf in jax.tree.leaves(snapshot.params)
               if np.issubdtype(np.asarray(leaf).dtype, np.floating))
