"""Simulated NMP system: the environment AIMM optimizes (paper §5-§6)."""
from repro.nmp import partition  # noqa: F401
from repro.nmp.config import NMPConfig  # noqa: F401
from repro.nmp.continual import PolicyStore, StreamResult, run_stream  # noqa: F401
from repro.nmp.engine import EpisodeResult, run_episode, run_program  # noqa: F401
from repro.nmp.plan import Envelope, GridPlan, plan_grid  # noqa: F401
from repro.nmp.scenarios import (Scenario, build_stream,  # noqa: F401
                                 continual_stream, seed_variants,
                                 tenant_fleet, tenant_stream)
from repro.nmp.serving import MappingServer, solo_stream  # noqa: F401
from repro.nmp.sweep import SweepResult, run_grid  # noqa: F401
from repro.nmp.topology import TOPOLOGIES, Topology, get_topology  # noqa: F401
from repro.nmp.traces import APPS, Trace, make_trace, merge_traces  # noqa: F401
