"""Scenario registry: named grids of (trace, technique, mapper, seed) cells.

A `Scenario` is one lane of a batched sweep — everything `sweep.run_grid`
needs to simulate one (workload, technique, mapper) cell for some number of
chained episodes. Grid builders cover the paper's experiment families:

  single_program_grid : app x technique x mapper x seed (Figs. 6-10)
  multi_program_grid  : merged co-running apps, optional HOARD allocation
                        (Fig. 12 protocol)
  forced_action_grid  : scripted-policy ablations, one lane per AIMM action
                        (mechanism-ceiling studies)
  topology_grid       : app x interconnect x mapper — the topology axis
                        (`Scenario.topology` names a builder in
                        `nmp.topology.TOPOLOGIES`; the plan layer compiles
                        one program per topology group, so a mixed grid is
                        still a handful of batched sweeps)
  continual_stream    : an *ordered* sequence of program phases (app
                        switches, co-runner arrival/departure) — one grid
                        per phase, the learned-AIMM lane of every phase
                        tagged with a shared `lineage` so
                        `continual.run_stream` threads one DQN through the
                        whole stream via chained `run_grid` calls

  tenant_stream /
  tenant_fleet        : single-lane program-switch streams for serving
                        tenants — one scenario per phase, many tenants
                        sharing Trace objects; the workload unit of the
                        multi-tenant mapping service (`nmp.serving`)

`GRIDS` maps names to builders so benchmarks/examples can request a standard
grid by name (`build("single", apps=..., n_ops=...)`); `STREAMS` does the
same for phase streams (`build_stream("switch", ...)`).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Sequence

import numpy as np

from repro.core.actions import N_ACTIONS
from repro.nmp.config import NMPConfig
from repro.nmp.paging import hoard_alloc
from repro.nmp.traces import Trace, make_trace, merge_traces, program_of_page


@dataclasses.dataclass
class Scenario:
    """One lane of a sweep: a trace plus its technique/mapper/seed protocol."""
    name: str
    trace: Trace
    technique: str = "bnmp"
    mapper: str = "none"
    seed: int = 0
    episodes: int = 1
    eval_episode: bool = False       # append a greedy (explore=False) episode
    forced_action: int = -1          # >= 0: scripted policy, no DQN
    page_table: np.ndarray | None = None
    lineage: str | None = None       # PolicyStore tag: warm-start the lane's
                                     # DQN from the tag (cold-start the
                                     # lineage if absent) and write the final
                                     # agent back — None = plain cold start
    topology: str | None = None      # cube interconnect this lane simulates
                                     # (a name in nmp.topology.TOPOLOGIES);
                                     # None = inherit the sweep NMPConfig's
                                     # topology.  Lanes of different
                                     # topologies have different link spaces,
                                     # so the plan layer compiles one program
                                     # per topology group.

    @property
    def total_episodes(self) -> int:
        return self.episodes + (1 if self.eval_episode else 0)

    def fold_key(self) -> tuple:
        """Identity of this scenario modulo its seed (and seed-derived name).

        Scenarios sharing a fold key are replicas of one experiment cell at
        different seeds: the sweep plan layer folds them into a single lane
        with a vmapped seed axis (`nmp.plan.plan_grid`), so they share one
        copy of the trace arrays and report variance bands together.  Traces
        fold by object identity — the grid builders below reuse one Trace
        across the seeds of a cell, which is what makes folding effective."""
        pt = self.page_table.tobytes() if self.page_table is not None else None
        return (id(self.trace), self.technique, self.mapper, self.episodes,
                self.eval_episode, self.forced_action, pt, self.lineage,
                self.topology)


def seed_variants(sc: Scenario, seeds: Sequence[int]) -> list[Scenario]:
    """Grid-spec constructor: replicate one cell across `seeds` so the plan
    layer folds them into a single seed-vmapped lane (the scenarios share
    `sc`'s Trace object by construction)."""
    return [dataclasses.replace(sc, name=f"{sc.name}/s{seed}", seed=seed)
            for seed in seeds]


def single_program_grid(apps: Sequence[str] = ("KM", "RBM", "SPMV"),
                        techniques: Sequence[str] = ("bnmp",),
                        mappers: Sequence[str] = ("none", "tom", "aimm"),
                        n_ops: int = 4096, seeds: Sequence[int] = (0,),
                        episodes: int = 1, aimm_episodes: int | None = None,
                        eval_episode: bool = False) -> list[Scenario]:
    """The paper's core grid. AIMM cells may train longer (`aimm_episodes`)
    than the deterministic baselines, which need a single episode."""
    out = []
    for app in apps:
        tr = make_trace(app, n_ops=n_ops)
        for tech in techniques:
            for mapper in mappers:
                for seed in seeds:
                    eps = (aimm_episodes if (mapper == "aimm"
                                             and aimm_episodes is not None)
                           else episodes)
                    out.append(Scenario(
                        name=f"{app}/{tech}/{mapper}/s{seed}",
                        trace=tr, technique=tech, mapper=mapper, seed=seed,
                        episodes=eps,
                        eval_episode=eval_episode and mapper == "aimm"))
    return out


DEFAULT_COMBOS = (
    ("SC-KM", ("SC", "KM")),
    ("LUD-RBM-SPMV", ("LUD", "RBM", "SPMV")),
    ("SC-KM-RD-MAC", ("SC", "KM", "RD", "MAC")),
)


def multi_program_grid(combos: Iterable[tuple[str, Sequence[str]]] = DEFAULT_COMBOS,
                       n_ops_per_app: int = 4096,
                       cfg: NMPConfig = NMPConfig(),
                       technique: str = "bnmp",
                       episodes: int = 1, aimm_episodes: int | None = None,
                       seeds: Sequence[int] = (0,)) -> list[Scenario]:
    """Fig. 12 protocol per combo: shared BNMP baseline, BNMP+HOARD, and
    BNMP+HOARD+AIMM lanes."""
    out = []
    for name, combo in combos:
        tr = merge_traces([make_trace(a, n_ops=n_ops_per_app) for a in combo])
        hoard = hoard_alloc(tr.n_pages, cfg, program_of_page(tr))
        for seed in seeds:
            out.append(Scenario(name=f"{name}/shared/s{seed}", trace=tr,
                                technique=technique, seed=seed,
                                episodes=episodes))
            out.append(Scenario(name=f"{name}/hoard/s{seed}", trace=tr,
                                technique=technique, seed=seed,
                                episodes=episodes, page_table=hoard))
            out.append(Scenario(name=f"{name}/hoard+aimm/s{seed}", trace=tr,
                                technique=technique, mapper="aimm", seed=seed,
                                episodes=aimm_episodes or episodes,
                                page_table=hoard))
    return out


def forced_action_grid(app: str = "SPMV", n_ops: int = 2048,
                       technique: str = "bnmp",
                       actions: Sequence[int] = tuple(range(N_ACTIONS)),
                       seeds: Sequence[int] = (0,)) -> list[Scenario]:
    """Scripted-policy ablation: one AIMM lane per forced action."""
    tr = make_trace(app, n_ops=n_ops)
    return [Scenario(name=f"{app}/{technique}/forced{a}/s{seed}", trace=tr,
                     technique=technique, mapper="aimm", seed=seed,
                     forced_action=a)
            for a in actions for seed in seeds]


def topology_grid(apps: Sequence[str] = ("KM",),
                  topologies: Sequence[str] | None = None,
                  techniques: Sequence[str] = ("bnmp",),
                  mappers: Sequence[str] = ("none", "aimm"),
                  n_ops: int = 2048, seeds: Sequence[int] = (0,),
                  episodes: int = 1, aimm_episodes: int | None = None,
                  eval_episode: bool = False) -> list[Scenario]:
    """The topology axis: app x interconnect x technique x mapper x seed.

    One lane per cell, each tagged with its `Scenario.topology`; the plan
    layer groups lanes by topology (different interconnects have different
    link spaces) and compiles one program per group, so the whole axis is
    still a handful of batched sweeps.  The default mapper pair
    ("none", "aimm") is the paper's central question per interconnect:
    does the learned mapping beat the unmanaged baseline?"""
    from repro.nmp.topology import TOPOLOGIES, validate_topology
    topologies = tuple(TOPOLOGIES) if topologies is None else tuple(topologies)
    for t in topologies:
        validate_topology(t)
    out = []
    for app in apps:
        tr = make_trace(app, n_ops=n_ops)
        for topo in topologies:
            for tech in techniques:
                for mapper in mappers:
                    for seed in seeds:
                        eps = (aimm_episodes
                               if (mapper == "aimm"
                                   and aimm_episodes is not None)
                               else episodes)
                        out.append(Scenario(
                            name=f"{app}/{topo}/{tech}/{mapper}/s{seed}",
                            trace=tr, technique=tech, mapper=mapper,
                            seed=seed, episodes=eps, topology=topo,
                            eval_episode=eval_episode and mapper == "aimm"))
    return out


# Default program-switch stream (phase name, live app set): a single program,
# a co-runner arriving, the original program departing.  The lineage-tagged
# AIMM lane lives through all three phases.
DEFAULT_STREAM = (
    ("KM", ("KM",)),
    ("KM+SC", ("KM", "SC")),
    ("SC", ("SC",)),
)


def continual_stream(phases: Iterable[tuple[str, Sequence[str]]] = DEFAULT_STREAM,
                     n_ops_per_app: int = 2048,
                     technique: str = "bnmp",
                     episodes: int = 2,
                     lineage: str | None = "stream",
                     seed: int = 0,
                     include_baseline: bool = True,
                     interleave: int = 32) -> list[list[Scenario]]:
    """Ordered program-phase stream for continual learning (the paper's
    "continuously evaluates and learns ... for any application" claim).

    Each phase is one grid: the live app set of that phase — merged
    round-robin from *per-app traces* when programs co-run, so arrival/
    departure re-uses the same per-app access patterns rather than one
    pre-merged blob — with a learned-AIMM lane tagged `lineage` (plus an
    unmanaged baseline lane when `include_baseline`).  Execute the phases in
    order with `continual.run_stream` (chained `sweep.run_grid` calls
    threading one PolicyStore) and the DQN lives through every app switch;
    with `lineage=None` every phase cold-starts instead (the ablation
    baseline)."""
    app_traces: dict[str, object] = {}
    for _, apps in phases:
        for app in apps:
            if app not in app_traces:
                app_traces[app] = make_trace(app, n_ops=n_ops_per_app)
    stream = []
    for pi, (name, apps) in enumerate(phases):
        tr = (app_traces[apps[0]] if len(apps) == 1 else
              merge_traces([app_traces[a] for a in apps],
                           interleave=interleave))
        grid = []
        if include_baseline:
            grid.append(Scenario(name=f"p{pi}:{name}/base", trace=tr,
                                 technique=technique, seed=seed))
        grid.append(Scenario(name=f"p{pi}:{name}/aimm", trace=tr,
                             technique=technique, mapper="aimm", seed=seed,
                             episodes=episodes, lineage=lineage))
        stream.append(grid)
    return stream


def tenant_stream(apps: Sequence[str] = ("KM", "SC"),
                  n_phases: int | None = None,
                  n_ops_per_app: int = 512,
                  technique: str = "bnmp",
                  episodes: int = 1,
                  lineage: str | None = None,
                  seed: int = 0,
                  traces: dict | None = None) -> list[list[Scenario]]:
    """Single-lane program-switch stream for one serving tenant.

    Each phase is one learned-AIMM scenario over the next app in the cycle
    (`apps` repeated up to `n_phases`) — the unit of work a
    `serving.MappingServer` slot executes per service tick.  `lineage` tags
    the lane so `continual.run_stream` can also execute the stream solo (the
    serving layer re-tags with the tenant id itself); pass a shared `traces`
    dict so a whole tenant fleet reuses one Trace per (app, n_ops)."""
    n_phases = len(apps) if n_phases is None else n_phases
    traces = traces if traces is not None else {}
    stream = []
    for pi in range(n_phases):
        app = apps[pi % len(apps)]
        key = (app, n_ops_per_app)
        if key not in traces:
            traces[key] = make_trace(app, n_ops=n_ops_per_app)
        stream.append([Scenario(
            name=f"p{pi}:{app}/aimm", trace=traces[key],
            technique=technique, mapper="aimm", seed=seed,
            episodes=episodes, lineage=lineage)])
    return stream


def tenant_fleet(n_tenants: int = 8,
                 apps: Sequence[str] = ("KM", "SC", "PR", "SPMV"),
                 n_phases: int = 2,
                 n_ops_per_app: int = 512,
                 technique: str = "bnmp",
                 episodes: int = 1,
                 seed0: int = 0) -> dict[str, list[list[Scenario]]]:
    """A heterogeneous fleet of single-lane tenant streams for the serving
    layer: tenant `t<i>` cycles through `apps` starting at offset i with
    seed `seed0 + i`, and all tenants share one Trace object per
    (app, n_ops) — the many-concurrent-tenants workload of the
    multi-tenant mapping service (see nmp.serving / bench_serving)."""
    traces: dict = {}
    return {
        f"t{i:03d}": tenant_stream(
            apps=tuple(apps[(i + k) % len(apps)] for k in range(len(apps))),
            n_phases=n_phases, n_ops_per_app=n_ops_per_app,
            technique=technique, episodes=episodes, seed=seed0 + i,
            traces=traces)
        for i in range(n_tenants)}


GRIDS: dict[str, Callable[..., list[Scenario]]] = {
    "single": single_program_grid,
    "multi": multi_program_grid,
    "ablation": forced_action_grid,
    "topology": topology_grid,
}

STREAMS: dict[str, Callable[..., list[list[Scenario]]]] = {
    "switch": continual_stream,
    "tenant": tenant_stream,
}


def build(name: str, **kw) -> list[Scenario]:
    """Build a named grid (see GRIDS) with builder-specific overrides."""
    return GRIDS[name](**kw)


def build_stream(name: str, **kw) -> list[list[Scenario]]:
    """Build a named phase stream (see STREAMS) — one grid per phase, to be
    executed in order by `continual.run_stream`."""
    return STREAMS[name](**kw)
