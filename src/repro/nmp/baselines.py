"""NMP techniques and mapping baselines (paper §6.3).

Schedulers (pick the compute cube for each windowed op, vectorized):
  BNMP : Active-Routing-style — compute at the destination operand's cube.
  LDB  : load-balancing — compute at the first source's cube (sources
         outnumber destinations, so this spreads NMP-table load).
  PEI  : cache-aware instruction offloading — if one source hits the CPU
         cache, offload the op (with the cached value) to the *other* source's
         cube; if both hit, offload to src1's cube; if neither, behave like
         BNMP (locality-aware default).

Mappers:
  TOM  : epoch-profiled physical remapping — evaluate K candidate
         consecutive-page stride-hash mappings for a profiling window each,
         then commit the best co-locating mapping for the epoch group.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.nmp.config import NMPConfig

BNMP, LDB, PEI = "bnmp", "ldb", "pei"
TECHNIQUES = (BNMP, LDB, PEI)


def schedule(technique: str, dcube, s1cube, s2cube, hot1, hot2):
    """Vectorized compute-cube selection. hot1/hot2: bool, PEI cache-hit flags."""
    if technique == BNMP:
        return dcube
    if technique == LDB:
        return s1cube
    if technique == PEI:
        neither = ~(hot1 | hot2)
        both = hot1 & hot2
        cc = jnp.where(hot1, s2cube, s1cube)          # offload to the missing side
        cc = jnp.where(both, s1cube, cc)
        cc = jnp.where(neither, dcube, cc)
        return cc
    raise ValueError(technique)


def schedule_by_id(tech_id, dcube, s1cube, s2cube, hot1, hot2):
    """`schedule` with a *traced* technique id (index into TECHNIQUES).

    All three policies are evaluated and the lane's one is selected, so one
    compiled program can serve a batch of scenarios with mixed techniques.
    """
    pei = schedule(PEI, dcube, s1cube, s2cube, hot1, hot2)
    return jnp.where(tech_id == TECHNIQUES.index(PEI), pei,
                     jnp.where(tech_id == TECHNIQUES.index(LDB), s1cube,
                               dcube))


# ---------------------------------------------------------------------------
# TOM
# ---------------------------------------------------------------------------

def tom_candidates(n_pages: int, cfg: NMPConfig, n_candidates: int = 6) -> jnp.ndarray:
    """Candidate page->cube mappings: consecutive-page groups of stride 2^k
    hashed round-robin over cubes (the paper's 'best data co-location' family).

    Returns (K, n_pages) int32.
    """
    pages = jnp.arange(n_pages)
    cands = []
    for k in range(n_candidates):
        stride = 1 << k
        cands.append(((pages // stride) % cfg.n_cubes).astype(jnp.int32))
    return jnp.stack(cands)


def tom_colocation_score(mapping: jnp.ndarray, dest, src1, src2, valid,
                         n_cubes: int = 16) -> jnp.ndarray:
    """Paper: pick the candidate with best co-location and least data movement.

    Score = operand co-location fraction minus a load-imbalance penalty (a
    perfectly co-locating mapping that funnels every op into one cube moves all
    its traffic through one region — the 'data movement' TOM avoids)."""
    d, a, b = mapping[dest], mapping[src1], mapping[src2]
    co = ((a == d).astype(jnp.float32) + (b == d).astype(jnp.float32)) / 2.0
    co_frac = jnp.sum(co * valid) / jnp.maximum(jnp.sum(valid), 1.0)
    ops_c = jnp.zeros((n_cubes,)).at[d].add(valid)
    total = jnp.maximum(jnp.sum(valid), 1.0)
    imb = (jnp.max(ops_c) / total - 1.0 / n_cubes) / (1.0 - 1.0 / n_cubes)
    return co_frac - 0.5 * jnp.clip(imb, 0.0, 1.0)
