"""Paging structures: page->cube table, page-info cache (§5.1), allocators.

The virtual->physical mapping is modeled at its actionable granularity: a
`page -> cube` table (which memory cube hosts the page frame). Two initial
allocation policies are provided:

  default_alloc : round-robin interleave across cubes (the physical-to-DRAM
                  hash of a conventional controller),
  hoard_alloc   : NMP-aware HOARD (§6.3) — each program's pages are allocated
                  from per-program chunks so a program's data is physically
                  co-located (contiguous cube regions).

The page-info cache is the paper's fully-associative, LFU-evicted structure in
each MC, holding per-page access/migration counters plus hop / latency /
migration-latency / action histories. We model the caches of all MCs as one
pooled array (MCs take round-robin turns feeding the agent, so the pool is
what the agent effectively sees).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.nmp.config import NMPConfig


def default_alloc(n_pages: int, cfg: NMPConfig, seed: int = 0) -> np.ndarray:
    """Round-robin page interleaving across cubes."""
    return (np.arange(n_pages) % cfg.n_cubes).astype(np.int32)


def random_alloc(n_pages: int, cfg: NMPConfig, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.n_cubes, n_pages).astype(np.int32)


def hoard_alloc(n_pages: int, cfg: NMPConfig, program_of_page: np.ndarray,
                seed: int = 0) -> np.ndarray:
    """HOARD-style: thread/program-private chunks -> contiguous cube regions.

    Programs get contiguous spans of cubes proportional to their page counts;
    within a span, pages interleave across that span's cubes only.  Programs
    with zero pages (a program id gap, or a departed co-runner whose pages
    were freed) claim no cubes at all — every cube goes to the programs that
    actually hold pages, so a degenerate span can never starve them.  Spans
    are disjoint whenever the populated programs fit the cube count; with
    more populated programs than cubes, every program keeps a one-cube span
    and the spans wrap round-robin (overlap is then unavoidable, but stays
    balanced instead of piling onto cube 0).
    """
    program_of_page = np.asarray(program_of_page)
    if program_of_page.size != n_pages:
        raise ValueError(
            f"hoard_alloc: program_of_page has {program_of_page.size} "
            f"entries for n_pages={n_pages}; one owner per page expected")
    if n_pages == 0:
        # zero-page trace (e.g. every co-runner departed): nothing to place
        return np.zeros(0, np.int32)
    n_prog = int(program_of_page.max()) + 1
    counts = np.bincount(program_of_page, minlength=n_prog).astype(np.float64)
    pop = np.flatnonzero(counts > 0)          # populated programs only
    share = np.zeros(n_prog, int)
    share[pop] = np.maximum(
        np.round(counts[pop] / counts.sum() * cfg.n_cubes), 1).astype(int)
    while share.sum() > cfg.n_cubes and (share[pop] > 1).any():
        share[pop[np.argmax(share[pop])]] -= 1
    while share.sum() < cfg.n_cubes:
        share[pop[np.argmin(share[pop])]] += 1
    start = np.concatenate([[0], np.cumsum(share)[:-1]])
    table = np.zeros(n_pages, np.int32)
    for p in pop:
        idx = np.where(program_of_page == p)[0]
        span = max(share[p], 1)
        table[idx] = (start[p] + (np.arange(idx.size) % span)) % cfg.n_cubes
    return table


class PageInfoCache(NamedTuple):
    """Pooled MC page-info cache (paper §5.1). All arrays leading dim = entries."""
    tag: jnp.ndarray       # page id, -1 = empty
    freq: jnp.ndarray      # LFU counter
    accesses: jnp.ndarray  # total access count for the page
    migrations: jnp.ndarray
    hop_hist: jnp.ndarray  # (E, 8) communication hop counts
    lat_hist: jnp.ndarray  # (E, 8) round-trip packet latencies
    mig_hist: jnp.ndarray  # (E, 4) migration latencies
    act_hist: jnp.ndarray  # (E, 4) actions taken on the page


def init_page_cache(cfg: NMPConfig, hop_h=None, lat_h=None, mig_h=None,
                    act_h=None) -> PageInfoCache:
    """Empty pooled cache.  History depths default to the config's
    `hop_hist`/`lat_hist`/`mig_hist`/`act_hist` fields (paper defaults
    8/8/4/4); explicit arguments override per call."""
    hop_h = cfg.hop_hist if hop_h is None else hop_h
    lat_h = cfg.lat_hist if lat_h is None else lat_h
    mig_h = cfg.mig_hist if mig_h is None else mig_h
    act_h = cfg.act_hist if act_h is None else act_h
    E = cfg.page_cache_entries
    return PageInfoCache(
        tag=jnp.full((E,), -1, jnp.int32),
        freq=jnp.zeros((E,), jnp.float32),
        accesses=jnp.zeros((E,), jnp.float32),
        migrations=jnp.zeros((E,), jnp.float32),
        hop_hist=jnp.zeros((E, hop_h), jnp.float32),
        lat_hist=jnp.zeros((E, lat_h), jnp.float32),
        mig_hist=jnp.zeros((E, mig_h), jnp.float32),
        act_hist=jnp.zeros((E, act_h), jnp.float32),
    )


def lookup_or_insert(cache: PageInfoCache, page: jnp.ndarray
                     ) -> tuple[PageInfoCache, jnp.ndarray]:
    """Find `page`'s entry; on miss, LFU-evict (victim content abandoned, §5.1).

    Returns (cache, entry_index).
    """
    hit = cache.tag == page
    found = jnp.any(hit)
    hit_idx = jnp.argmax(hit)
    victim = jnp.argmin(jnp.where(cache.tag < 0, -1.0, cache.freq))
    idx = jnp.where(found, hit_idx, victim).astype(jnp.int32)

    def clear(arr):
        return arr.at[idx].set(jnp.zeros_like(arr[idx]))

    cache = cache._replace(
        tag=cache.tag.at[idx].set(page.astype(jnp.int32)),
        freq=jnp.where(found, cache.freq, cache.freq.at[idx].set(0.0)),
        accesses=jnp.where(found, cache.accesses, clear(cache.accesses)),
        migrations=jnp.where(found, cache.migrations, clear(cache.migrations)),
        hop_hist=jnp.where(found, cache.hop_hist, clear(cache.hop_hist)),
        lat_hist=jnp.where(found, cache.lat_hist, clear(cache.lat_hist)),
        mig_hist=jnp.where(found, cache.mig_hist, clear(cache.mig_hist)),
        act_hist=jnp.where(found, cache.act_hist, clear(cache.act_hist)),
    )
    return cache, idx


def push_hist(hist: jnp.ndarray, idx: jnp.ndarray, value: jnp.ndarray) -> jnp.ndarray:
    """Shift entry `idx`'s history left and append `value`."""
    row = hist[idx]
    row = jnp.concatenate([row[1:], value[None].astype(jnp.float32)])
    return hist.at[idx].set(row)
