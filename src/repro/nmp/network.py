"""Memory-cube network model: 2D mesh, static XY routing, link-load histograms.

Link indexing (undirected, contention aggregates both directions):
  horizontal link (y, x <-> x+1):  id = y * (X-1) + x          for x in [0, X-1)
  vertical   link (x, y <-> y+1):  id = H + x * (Y-1) + y      for y in [0, Y-1)
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.nmp.config import NMPConfig


def hop_count(a: jnp.ndarray, b: jnp.ndarray, mesh_x: int) -> jnp.ndarray:
    """Manhattan distance between cube ids (XY routing path length)."""
    ax, ay = a % mesh_x, a // mesh_x
    bx, by = b % mesh_x, b // mesh_x
    return jnp.abs(ax - bx) + jnp.abs(ay - by)


def n_links(cfg: NMPConfig) -> int:
    return cfg.mesh_y * (cfg.mesh_x - 1) + cfg.mesh_x * (cfg.mesh_y - 1)


def link_loads(src: jnp.ndarray, dst: jnp.ndarray, weight: jnp.ndarray,
               cfg: NMPConfig) -> jnp.ndarray:
    """Accumulate flow `weight` (flits) over every link on each XY route.

    src, dst: (F,) cube ids; weight: (F,) flits. Returns (n_links,) loads.
    XY routing: traverse X at the source row, then Y at the destination column.
    Fully vectorized via indicator outer-products (mesh dims are tiny).
    """
    X, Y = cfg.mesh_x, cfg.mesh_y
    sx, sy = src % X, src // X
    dx, dy = dst % X, dst // X

    lo_x, hi_x = jnp.minimum(sx, dx), jnp.maximum(sx, dx)
    xs = jnp.arange(X - 1)
    ind_h = ((xs[None, :] >= lo_x[:, None]) & (xs[None, :] < hi_x[:, None]))
    row_oh = (jnp.arange(Y)[None, :] == sy[:, None])
    # loads_h[y, x] = sum_f weight_f * ind_h[f, x] * row_oh[f, y]
    loads_h = jnp.einsum("f,fy,fx->yx", weight.astype(jnp.float32),
                         row_oh.astype(jnp.float32), ind_h.astype(jnp.float32))

    lo_y, hi_y = jnp.minimum(sy, dy), jnp.maximum(sy, dy)
    ys = jnp.arange(Y - 1)
    ind_v = ((ys[None, :] >= lo_y[:, None]) & (ys[None, :] < hi_y[:, None]))
    col_oh = (jnp.arange(X)[None, :] == dx[:, None])
    loads_v = jnp.einsum("f,fx,fy->xy", weight.astype(jnp.float32),
                         col_oh.astype(jnp.float32), ind_v.astype(jnp.float32))

    return jnp.concatenate([loads_h.reshape(-1), loads_v.reshape(-1)])


def nearest_mc(cfg: NMPConfig) -> jnp.ndarray:
    """Static cube -> nearest-MC index map (ties broken by MC order)."""
    cubes = jnp.arange(cfg.n_cubes)
    mcs = jnp.asarray(cfg.mc_cubes)
    d = hop_count(cubes[:, None], mcs[None, :], cfg.mesh_x)
    return jnp.argmin(d, axis=1).astype(jnp.int32)
