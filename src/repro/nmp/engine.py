"""Trace-driven, epoch-based NMP timing engine.

The entire simulate -> observe -> act -> learn loop is a single `jax.lax.scan`
(one step per agent invocation epoch), so an AIMM run is one compiled XLA
program: the continual-learning agent literally trains inside the simulator.

Epoch model (documented cost model; see DESIGN.md §2):

  window   : the next `window_sizes[interval_level]` ops of the trace
  schedule : technique (BNMP/LDB/PEI) picks a compute cube per op, then the
             AIMM compute-remap table overrides per-page
  route    : packets s1->c, s2->c, c->d over the topology's precomputed
             routes (nmp.topology: hop matrix + route-link incidence tensor,
             built host-side per interconnect — XY on the paper's mesh,
             minimal routes on torus/ring/dragonfly); per-link flit loads
             are one gather + einsum, never per-epoch route construction
  time     : cycles = mc_inject + max(compute, link, dram serialization)
             + mean latency + NMP-table overflow stalls + migration stalls
  feedback : OPC = ops/cycles; reward = sign(dOPC); state vector from
             system EMAs + hot-page info cache entry (paper Fig. 3)

Hot-path structure (this is the optimized cost model the benchmarks measure;
see benchmarks/README.md "The engine hot path"):

  * Every epoch is split into `_epoch_sim` (cost model, reward, state vector
    -- everything that does not depend on the agent's action) and
    `_epoch_apply` (action application + state commit).  Between the two, the
    full agent invocation -- replay push, minibatch TD step, Adam update,
    target sync, eps-greedy act -- runs under `jax.lax.cond` on "any lane
    invokes this epoch", so epochs between invocations (stride 2..4 at higher
    interval levels) skip the DQN machinery entirely instead of computing it
    and masking the result.  TOM's profiling-phase candidate scoring is gated
    the same way (`lax.cond` on "any lane is in a profiling phase", see
    `_tom_window_scores`), so the 8 commit-phase windows of every TOM period
    skip the K-candidate scoring.
  * The PEI hot-page threshold is a `lax.top_k` order statistic over a static
    envelope of the hottest pages (`BodyFlags.pei_k`), not an O(P log P) sort
    of every page's access EMA; it is compiled in only when the program/grid
    actually contains PEI lanes.
  * The row-buffer distinct-page count is an O(W) scatter-stamp: each access
    stamps its page with the epoch tag (`at[].max`), a page is "distinct"
    exactly when its stamp equals the current tag.  No per-epoch sort.
  * `BodyFlags` records which features (AIMM action machinery, TOM candidate
    scoring, PEI thresholding, a live DQN) any lane of the compiled program
    uses; unused features are statically skipped, which keeps a plain
    technique-comparison grid close to baseline cost.

Batching model (plan/partition/execute pipeline, see nmp.plan / nmp.partition
/ nmp.sweep): every per-trace quantity that used to be a Python static -- op
count, OPC-ring length, PEI hot-page sort index, technique, mapper, forced
action, exploration flag -- is carried as a traced `TraceCtx` scalar instead,
and every state update is gated on `has_ops`, so epochs past the end of a
(padded) trace are exact no-ops.  The epoch body itself is written per-lane
and `jax.vmap`ed over a scenario axis (the serial runner is the same body at
batch size 1), with the epoch scan *outside* the vmap so the
any-lane-invokes `lax.cond` is a genuine scalar branch; seed replicas of a
lane ride an inner seed-axis vmap that shares the lane's trace arrays
(`seed_axis=True` in `_epoch_batched`).  That makes one compiled program
valid for a whole stacked grid of scenarios -- shardable over a device mesh
along the lane axis -- and keeps the batched engine bit-identical to serial
runs (tests/test_sweep_equivalence.py, tests/test_engine_golden.py,
tests/test_plan_partition.py).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import actions as act_mod
from repro.core import agent as agent_mod
from repro.core.actions import (DEFAULT, FAR_COMPUTE, FAR_DATA, INC_INTERVAL,
                                DEC_INTERVAL, NEAR_COMPUTE, NEAR_DATA,
                                SOURCE_COMPUTE, N_ACTIONS)
from repro.core.agent import AgentConfig, AgentState
from repro.core.dqn import DQNConfig
from repro.core.reward import compute_reward
from repro.core.state import StateSpec, build_state
from repro.kernels.epoch_fused import ops as epoch_ops
from repro.nmp import baselines
from repro.nmp.config import NMPConfig
from repro.nmp.migration import migration_cost
from repro.nmp.paging import (PageInfoCache, default_alloc, init_page_cache,
                              lookup_or_insert, push_hist)
from repro.nmp.topology import get_topology
from repro.nmp.traces import Trace

MAPPERS = ("none", "tom", "aimm")
MAPPER_ID = {m: i for i, m in enumerate(MAPPERS)}
TECH_ID = {t: i for i, t in enumerate(baselines.TECHNIQUES)}

# Energy counter layout (see stats.py).
EN_PAGE_CACHE, EN_NMP_BUF, EN_MIG_Q, EN_MDMA, EN_WEIGHT, EN_REPLAY, \
    EN_STATE_BUF, EN_NET_BIT_HOPS, EN_MEM_BITS, EN_N = range(10)

# TOM control period: K profiling windows (one per candidate) + this many
# commit windows running the winner (shared by _epoch_sim's phase arithmetic
# and the driver's profiling-phase cond gate).
TOM_COMMIT_WINDOWS = 8


class TraceCtx(NamedTuple):
    """Per-scenario runtime context: everything that used to be a compile-time
    static but must vary across the lanes of a batched sweep."""
    n_ops: jnp.ndarray          # () i32 real op count (trace arrays may be padded)
    n_pages: jnp.ndarray        # () i32 real page count (tables may be padded)
    t_ring: jnp.ndarray         # () i32 effective OPC phase-ring length
    pei_idx: jnp.ndarray        # () i32 hot-threshold index into the ascending
                                #        sort of the *real* pages' access EMAs
    technique: jnp.ndarray      # () i32 index into baselines.TECHNIQUES
    mapper: jnp.ndarray         # () i32 index into MAPPERS
    forced_action: jnp.ndarray  # () i32 scripted action, -1 = learned policy
    explore: jnp.ndarray        # () bool ε-greedy exploration on/off


class BodyFlags(NamedTuple):
    """Static feature flags of one compiled epoch body.

    Derived from what the lanes of a program actually use (serial runs: the
    single lane; sweeps: the OR over a group's lanes).  A feature that no lane
    uses is skipped at trace time, not masked at run time, so e.g. a pure
    technique-comparison grid never builds the AIMM action machinery and a
    grid without PEI lanes never computes the hot-page threshold.  `pei_k` is
    the top_k envelope for the PEI threshold order statistic (0 = no PEI
    lanes).

    `share_seed_inv` switches the epoch driver's folded-seed path to compute
    the seed-invariant half of the cost model (`SharedEpoch`: op windows,
    valid masks, row-buffer stamps, PEI thresholds, page-touch counts) once
    per lane and broadcast it across the S seed replicas instead of
    recomputing it S times.  Bit-identical either way; compiled out (flag
    False) when the executed seed axis is width 1.

    `epoch_backend` is the resolved REPRO_EPOCH_BACKEND (one of jnp /
    pallas / pallas_interpret — see repro.kernels.epoch_fused.ops): the
    epoch simulation core runs either as the historical gather/einsum jnp
    path or as the fused Pallas kernel.  Carrying it here (a static jit
    argument everywhere flags flow) means flipping the knob selects a
    distinct compiled program instead of being frozen into a resident one."""
    has_agent: bool = False     # a live DQN (aimm lanes with a learned policy)
    any_aimm: bool = False      # hot-page selection / action application
    any_tom: bool = False       # TOM candidate scoring + commit
    pei_k: int = 0              # static top_k width for the PEI threshold
    share_seed_inv: bool = False  # hoist seed-invariant work out of seed vmap
    epoch_backend: str = "jnp"  # resolved epoch-core backend (see above)


def pei_hot_index(n_pages: int, cfg: NMPConfig) -> int:
    """Sort index of the PEI hot-page threshold among the real pages.

    Matches the historical static indexing `sorted[int(P*(1-frac)) - 1]`
    (including Python negative-index wraparound for tiny P).
    """
    return (int(n_pages * (1 - cfg.pei_hot_frac)) - 1) % n_pages


def pei_top_k(n_pages: int, cfg: NMPConfig) -> int:
    """top_k width needed to read the PEI threshold as the m-th largest EMA."""
    return n_pages - pei_hot_index(n_pages, cfg)


def episode_flags(trace: Trace, cfg: NMPConfig, technique: str, mapper: str,
                  forced_action: int = -1) -> BodyFlags:
    """Static body flags for one serial episode."""
    return BodyFlags(
        has_agent=mapper == "aimm" and forced_action < 0,
        any_aimm=mapper == "aimm",
        any_tom=mapper == "tom",
        pei_k=pei_top_k(trace.n_pages, cfg) if technique == "pei" else 0,
        epoch_backend=epoch_ops.resolve_backend(),
    )


def serial_epochs(n_ops: int, cfg: NMPConfig) -> int:
    """Number of epoch-scan steps needed to consume `n_ops` (exactly; the
    historical +1 all-padding epoch was a no-op by construction)."""
    return int(np.ceil(n_ops / cfg.epoch_ops))


def phase_ring_len(trace: Trace, cfg: NMPConfig) -> int:
    """Length of the same-phase OPC reference ring for one trace."""
    iter_ops = trace.iter_ops or trace.n_ops
    n_epochs = serial_epochs(trace.n_ops, cfg)
    return int(np.clip(iter_ops // cfg.epoch_ops, 1, n_epochs + 1))


def make_ctx(trace: Trace, cfg: NMPConfig, technique: str, mapper: str,
             forced_action: int = -1, explore: bool = True) -> TraceCtx:
    assert mapper in MAPPERS and technique in baselines.TECHNIQUES
    return TraceCtx(
        n_ops=jnp.asarray(trace.n_ops, jnp.int32),
        n_pages=jnp.asarray(trace.n_pages, jnp.int32),
        t_ring=jnp.asarray(phase_ring_len(trace, cfg), jnp.int32),
        pei_idx=jnp.asarray(pei_hot_index(trace.n_pages, cfg), jnp.int32),
        technique=jnp.asarray(TECH_ID[technique], jnp.int32),
        mapper=jnp.asarray(MAPPER_ID[mapper], jnp.int32),
        forced_action=jnp.asarray(forced_action, jnp.int32),
        explore=jnp.asarray(explore, bool),
    )


class EnvState(NamedTuple):
    page_to_cube: jnp.ndarray      # (P,) i32 data mapping
    compute_remap: jnp.ndarray     # (P,) i32, -1 = none
    op_ptr: jnp.ndarray            # () i32
    interval_level: jnp.ndarray    # () i32 (stride-1 epochs between invocations)
    since_invoke: jnp.ndarray      # () i32 epochs since last agent invocation
    span_sum: jnp.ndarray          # () f32 OPC sum of current action tenure
    span_n: jnp.ndarray            # () f32
    prev_span_mean: jnp.ndarray    # () f32 (-1 = none yet)
    opc_ring: jnp.ndarray          # (T,) f32 per-phase OPC one iteration ago
    ref_sum: jnp.ndarray           # () f32 same-phase reference sum for tenure
    ref_n: jnp.ndarray             # () f32
    page_access_ema: jnp.ndarray   # (P,) f32
    rb_stamp: jnp.ndarray          # (P+1,) i32 epoch tag of the page's last
                                   #  access (row-buffer distinct-count stamp;
                                   #  row P is the invalid-access sink)
    nmp_occ: jnp.ndarray           # (C,) f32
    rb_hit: jnp.ndarray            # (C,) f32
    mc_queue: jnp.ndarray          # (M,) f32
    global_act_hist: jnp.ndarray   # (Hg,) i32
    cache: PageInfoCache
    pending_mig_loads: jnp.ndarray  # (L,) f32
    pending_mig_stall: jnp.ndarray  # () f32
    prev_state_vec: jnp.ndarray    # (S,) f32
    prev_action: jnp.ndarray       # () i32
    recent_pages: jnp.ndarray      # (R,) i32 pages acted on recently (-1 empty)
    remap_age: jnp.ndarray         # (P,) i32 epochs since compute remap set
    rng: jax.Array
    # TOM state
    tom_scores: jnp.ndarray        # (K,) f32
    tom_active: jnp.ndarray        # () i32 candidate idx in use (-1 = default)
    # cumulative stats
    cycles: jnp.ndarray
    ops_done: jnp.ndarray
    hops_sum: jnp.ndarray
    util_sum: jnp.ndarray
    epochs: jnp.ndarray
    mig_count: jnp.ndarray
    mig_page_mask: jnp.ndarray     # (P,) f32
    access_total: jnp.ndarray
    access_on_migrated: jnp.ndarray
    energy: jnp.ndarray            # (EN_N,) f64-ish counters (f32)


class EpisodeResult(NamedTuple):
    env: EnvState
    agent: AgentState | None
    metrics: dict[str, jnp.ndarray]   # per-epoch stacked


def _init_env(page_table: jnp.ndarray, cfg: NMPConfig, spec: StateSpec,
              seed, t_ring: int = 1) -> EnvState:
    """Fresh env state. `page_table` fixes P (possibly padded); `seed` may be a
    traced scalar (episode scans re-init inside jit); `t_ring` is the static
    ring buffer size (>= every lane's effective TraceCtx.t_ring)."""
    page_table = jnp.asarray(page_table, jnp.int32)
    P = page_table.shape[0]
    C, M = cfg.n_cubes, cfg.n_mcs
    L = get_topology(cfg).n_links
    return EnvState(
        page_to_cube=page_table,
        compute_remap=jnp.full((P,), -1, jnp.int32),
        op_ptr=jnp.zeros((), jnp.int32),
        interval_level=jnp.zeros((), jnp.int32),    # invoke every epoch initially
        since_invoke=jnp.zeros((), jnp.int32),
        span_sum=jnp.zeros(()),
        span_n=jnp.zeros(()),
        prev_span_mean=jnp.full((), -1.0),
        opc_ring=jnp.zeros((t_ring,)),
        ref_sum=jnp.zeros(()),
        ref_n=jnp.zeros(()),
        page_access_ema=jnp.zeros((P,)),
        rb_stamp=jnp.zeros((P + 1,), jnp.int32),
        nmp_occ=jnp.zeros((C,)),
        rb_hit=jnp.full((C,), 0.5),
        mc_queue=jnp.zeros((M,)),
        global_act_hist=jnp.zeros((spec.global_act_hist,), jnp.int32),
        cache=init_page_cache(cfg, spec.hop_hist, spec.lat_hist,
                              spec.mig_hist, spec.act_hist),
        pending_mig_loads=jnp.zeros((L,)),
        pending_mig_stall=jnp.zeros(()),
        prev_state_vec=jnp.zeros((spec.dim,)),
        prev_action=jnp.zeros((), jnp.int32),
        recent_pages=jnp.full((max(cfg.recent_ring, 1),), -1, jnp.int32),
        remap_age=jnp.zeros((P,), jnp.int32),
        rng=jax.random.PRNGKey(seed),
        tom_scores=jnp.zeros((6,)),
        tom_active=jnp.full((), -1, jnp.int32),
        cycles=jnp.zeros(()),
        ops_done=jnp.zeros(()),
        hops_sum=jnp.zeros(()),
        util_sum=jnp.zeros(()),
        epochs=jnp.zeros(()),
        mig_count=jnp.zeros(()),
        mig_page_mask=jnp.zeros((P,)),
        access_total=jnp.zeros(()),
        access_on_migrated=jnp.zeros(()),
        energy=jnp.zeros((EN_N,)),
    )


class EpochMid(NamedTuple):
    """Intermediate results handed from `_epoch_sim` to `_epoch_apply` (and to
    the agent invocation in between).  Everything here is per-lane; the epoch
    driver vmaps the halves and keeps the agent `lax.cond` un-vmapped."""
    valid: jnp.ndarray         # (W,) f32
    w_valid: jnp.ndarray       # () f32
    has_ops: jnp.ndarray       # () bool
    invoke: jnp.ndarray        # () bool
    dest: jnp.ndarray          # (W,) i32
    src1: jnp.ndarray          # (W,) i32
    src2: jnp.ndarray          # (W,) i32
    cycles: jnp.ndarray        # () f32
    opc: jnp.ndarray           # () f32
    span_sum: jnp.ndarray
    span_n: jnp.ndarray
    cur_mean: jnp.ndarray
    ref_sum: jnp.ndarray
    ref_n: jnp.ndarray
    opc_ring: jnp.ndarray
    reward: jnp.ndarray
    hops_total: jnp.ndarray
    mean_hops: jnp.ndarray
    util: jnp.ndarray
    nmp_occ: jnp.ndarray
    rb_hit: jnp.ndarray
    mc_queue: jnp.ndarray
    page_ema: jnp.ndarray
    rb_stamp: jnp.ndarray
    cache: PageInfoCache
    ent: jnp.ndarray
    hot_page: jnp.ndarray
    touches_hot: jnp.ndarray
    ccube_hot: jnp.ndarray
    svec: jnp.ndarray
    k_nbr: jax.Array
    env_rng: jax.Array
    tom_scores: jnp.ndarray
    tom_active: jnp.ndarray
    mig_stall_tom: jnp.ndarray
    migrated_tom: jnp.ndarray
    energy: jnp.ndarray        # action-independent counters already added


# ---------------------------------------------------------------------------
# One epoch: cost model (action-independent half)
# ---------------------------------------------------------------------------

def _fetch_window(env: EnvState, trace: dict, ctx: TraceCtx,
                  cfg: NMPConfig):
    """This epoch's op window: (dest, src1, src2, valid) sliced at `op_ptr`
    from the (pre-padded) trace arrays.  The single definition of the window
    fetch + validity mask, shared by `_epoch_sim` and the TOM profiling
    scorer so the two can never drift apart."""
    W = cfg.w_max
    window = jnp.asarray(cfg.epoch_ops, jnp.int32)
    sl = lambda a: jax.lax.dynamic_slice(a, (env.op_ptr,), (W,))
    dest, src1, src2 = sl(trace["dest"]), sl(trace["src1"]), sl(trace["src2"])
    idx = jnp.arange(W)
    valid = ((idx < window)
             & (env.op_ptr + idx < ctx.n_ops)).astype(jnp.float32)
    return dest, src1, src2, valid


class SharedEpoch(NamedTuple):
    """The seed-invariant half of one lane's epoch: every quantity below
    depends only on the op stream position (`op_ptr`/`epochs`), the trace
    arrays, and trace-derived accumulators (`page_access_ema`, `rb_stamp`)
    that evolve identically across seed replicas — never on the data
    mapping, routing, timing, or RNG, which are seed-dependent.  Under
    `BodyFlags.share_seed_inv` the epoch driver computes one SharedEpoch per
    lane and broadcasts it across the folded seed axis (inner vmap
    `in_axes=None`), so S replicas share one window fetch, one row-buffer
    stamp scatter, one PEI top_k and one touch-count scatter instead of S."""
    dest: jnp.ndarray          # (W,) i32 op window destination pages
    src1: jnp.ndarray          # (W,) i32
    src2: jnp.ndarray          # (W,) i32
    valid: jnp.ndarray         # (W,) f32 window validity mask
    w_valid: jnp.ndarray       # () f32
    has_ops: jnp.ndarray       # () bool
    rb_stamp: jnp.ndarray      # (P+1,) i32 updated row-buffer stamps
    rb_winner: jnp.ndarray     # (3W,) bool first-touch-of-epoch indicators
    page_ema: jnp.ndarray      # (P,) f32 updated access EMA (PEI programs)
    pei_hot1: jnp.ndarray | None  # (W,) bool src1 above the PEI threshold
    pei_hot2: jnp.ndarray | None  # (W,) bool
    touch_cnt: jnp.ndarray | None  # (P,) f32 window touch counts (AIMM)
    tom_scores: jnp.ndarray | None  # (K,) f32 TOM candidate scores (TOM)


def _shared_epoch(env: EnvState, trace: dict, ctx: TraceCtx, cfg: NMPConfig,
                  flags: BodyFlags,
                  tom_scores_all: jnp.ndarray | None = None) -> SharedEpoch:
    """Compute the seed-invariant epoch quantities from one lane's env (any
    seed replica — seed slot 0 by convention).  The stage math lives in
    repro.kernels.epoch_fused.ref (one source for the jnp path and the
    Pallas kernel body); bit-identical to the inline computations these
    replaced in `_epoch_sim`, on any backend."""
    dest, src1, src2, valid = _fetch_window(env, trace, ctx, cfg)
    w_valid = valid.sum()
    has_ops = w_valid > 0

    parts = epoch_ops.shared_parts(
        dest, src1, src2, valid, env.epochs, env.rb_stamp,
        env.page_access_ema, ctx.n_pages, ctx.pei_idx,
        pei_k=flags.pei_k, aimm=flags.any_aimm,
        backend=flags.epoch_backend)
    # Only the PEI threshold reads the access EMA; without PEI lanes the
    # decay + triple scatter is compiled out and the EMA rides unchanged.
    page_ema = (parts.page_ema if parts.page_ema is not None
                else env.page_access_ema)
    return SharedEpoch(dest=dest, src1=src1, src2=src2, valid=valid,
                       w_valid=w_valid, has_ops=has_ops,
                       rb_stamp=parts.rb_stamp, rb_winner=parts.rb_winner,
                       page_ema=page_ema, pei_hot1=parts.pei_hot1,
                       pei_hot2=parts.pei_hot2, touch_cnt=parts.touch_cnt,
                       tom_scores=tom_scores_all)


def _epoch_sim(env: EnvState, trace: dict, tom_cands: jnp.ndarray,
               ctx: TraceCtx, cfg: NMPConfig, spec: StateSpec,
               agent_cfg: AgentConfig, flags: BodyFlags,
               tom_scores_all: jnp.ndarray | None = None,
               shared: SharedEpoch | None = None) -> EpochMid:
    """Everything up to (but excluding) the agent's action: window fetch,
    scheduling, routing, timing, reward bookkeeping, hot-page selection and
    the state vector.  Runs per-lane (vmapped by the epoch driver).

    `tom_scores_all` is the (K,) candidate-score vector for this lane's
    window, computed by the epoch driver under its profiling-phase `lax.cond`
    (zeros when no lane is profiling — the per-lane select below never reads
    them in that case).

    `shared` carries the precomputed seed-invariant half (see SharedEpoch)
    when the driver hoists it out of the seed vmap; None (serial runs,
    S==1 programs) computes it inline — same ops, bit-identical."""
    P = env.page_to_cube.shape[0]
    C = cfg.n_cubes
    topo = get_topology(cfg)     # host-side tensors, trace-time constants
    is_tom = ctx.mapper == MAPPER_ID["tom"]
    is_aimm = ctx.mapper == MAPPER_ID["aimm"]

    # ---- seed-invariant half: window fetch, stamps, thresholds, counts ----
    # On a non-jnp backend with no precomputed SharedEpoch (serial runs,
    # S==1 programs), the shared half fuses into the same kernel launch as
    # the route half below instead of running as a separate stage.
    fused = shared is None and flags.epoch_backend != "jnp"
    if shared is None and not fused:
        shared = _shared_epoch(env, trace, ctx, cfg, flags, tom_scores_all)
    if fused:
        dest, src1, src2, valid = _fetch_window(env, trace, ctx, cfg)
        w_valid = valid.sum()
        has_ops = w_valid > 0
    else:
        dest, src1, src2, valid = (shared.dest, shared.src1, shared.src2,
                                   shared.valid)
        w_valid = shared.w_valid
        has_ops = shared.has_ops

    # ---- data mapping (TOM may override the page table) ----
    if flags.any_tom:
        eff_table = jnp.where(is_tom & (env.tom_active >= 0),
                              tom_cands[jnp.maximum(env.tom_active, 0)],
                              env.page_to_cube)
    else:
        eff_table = env.page_to_cube
    # ---- schedule + route + per-cube counts: the fused epoch core ----
    # Stage math lives in repro.kernels.epoch_fused (ref.py is the single
    # source for the jnp path and the Pallas kernel body): effective-table
    # gathers, technique scheduling (PEI hot-source placement, AIMM
    # compute-remap override), per-link flit loads, hop counts, and the
    # per-cube compute/access/row-buffer-distinct/MC-queue counts.  Counts
    # and route weights are exact small integers in f32, so every reduction
    # is bit-exact regardless of accumulation order or backend.
    if fused:
        sparts, rparts = epoch_ops.fused_parts(
            dest, src1, src2, valid, env.epochs, env.rb_stamp,
            env.page_access_ema, ctx.n_pages, ctx.pei_idx, eff_table,
            env.compute_remap, ctx.technique, is_aimm,
            env.pending_mig_loads, topo, pei_k=flags.pei_k,
            aimm=flags.any_aimm, n_mcs=cfg.n_mcs,
            packet_flits=cfg.packet_flits, backend=flags.epoch_backend)
        shared = SharedEpoch(
            dest=dest, src1=src1, src2=src2, valid=valid, w_valid=w_valid,
            has_ops=has_ops, rb_stamp=sparts.rb_stamp,
            rb_winner=sparts.rb_winner,
            page_ema=(sparts.page_ema if sparts.page_ema is not None
                      else env.page_access_ema),
            pei_hot1=sparts.pei_hot1, pei_hot2=sparts.pei_hot2,
            touch_cnt=sparts.touch_cnt, tom_scores=tom_scores_all)
    else:
        rparts = epoch_ops.route_parts(
            dest, src1, src2, valid, shared.rb_winner, shared.pei_hot1,
            shared.pei_hot2, eff_table, env.compute_remap, ctx.technique,
            is_aimm, env.pending_mig_loads, topo, pei_k=flags.pei_k,
            aimm=flags.any_aimm, n_mcs=cfg.n_mcs,
            packet_flits=cfg.packet_flits, backend=flags.epoch_backend)
    ccube, loads, hops_op = rparts.ccube, rparts.loads, rparts.hops_op
    ops_c, acc_c, distinct_c, mcq = (rparts.ops_c, rparts.acc_c,
                                     rparts.distinct_c, rparts.mcq)
    hops_total = jnp.sum(hops_op * valid)
    mean_hops = hops_total / jnp.maximum(w_valid, 1.0)

    # ---- per-cube compute load & NMP-table occupancy ----
    table_excess = jnp.maximum(ops_c - cfg.nmp_table_size, 0.0).sum()
    compute_serial = jnp.max(ops_c) * cfg.t_op / cfg.cube_issue_rate
    eff_cubes = jnp.square(ops_c.sum()) / jnp.maximum(jnp.sum(ops_c ** 2), 1.0)
    util = eff_cubes / C

    # ---- row-buffer model: distinct (cube,page) pairs accessed per cube ----
    # A page maps to exactly one cube, so distinct pairs == distinct pages.
    # O(W) scatter-stamp (shared half): stamp each accessed page with this
    # epoch's tag; an access is its page's first touch of the epoch iff it
    # won the stamp race (`rb_winner`).  Only the scatter-add of winner
    # indicators by the seed-dependent compute cube stays per-seed.
    rb_stamp = shared.rb_stamp
    hit_c = jnp.where(acc_c > 0, 1.0 - distinct_c / jnp.maximum(acc_c, 1.0), 0.5)
    lat_c = hit_c * cfg.t_dram_hit + (1 - hit_c) * cfg.t_dram_miss
    dram_serial = jnp.max(acc_c * lat_c) / (cfg.n_vaults * 4.0)

    # ---- epoch cycles & OPC ----
    mc_inject = w_valid / (cfg.n_mcs * cfg.mc_issue_rate)
    # Hottest-link serialization with superlinear queuing amplification: a link
    # loaded far above the network average queues disproportionately (3-stage
    # routers, token flow control), so imbalance costs more than linearly.
    mean_load = jnp.sum(loads) / loads.shape[0]
    imbalance = jnp.max(loads) / jnp.maximum(mean_load, 1.0)
    link_serial = jnp.max(loads) * (1.0 + (cfg.congestion_alpha - 1.0)
                                    * jnp.clip((imbalance - 1.0) / 4.0, 0.0, 1.0))
    mean_lat = (mean_hops * cfg.t_router + cfg.packet_flits
                + jnp.sum(acc_c * lat_c) / jnp.maximum(acc_c.sum(), 1.0))
    # agent invocation cadence: the interval actions control how many epochs an
    # action's tenure lasts (paper intervals {100,125,167,250} cycles, modeled
    # as {1,2,3,4} fixed-size epochs between invocations).
    stride = env.interval_level + 1
    invoke = (env.since_invoke + 1 >= stride) & has_ops
    agent_overhead = jnp.where(is_aimm & invoke, cfg.t_agent, 0.0)
    cycles = (agent_overhead + mc_inject
              + jnp.maximum(jnp.maximum(compute_serial, link_serial), dram_serial)
              + mean_lat + table_excess * cfg.t_op + env.pending_mig_stall)
    cycles = jnp.where(has_ops, cycles, 0.0)
    opc = jnp.where(has_ops, w_valid / jnp.maximum(cycles, 1.0), 0.0)
    # The performance monitor accumulates OPC over the current action's tenure.
    # Reward for the previous action (paper: +-1 on performance improvement or
    # degradation): compare the tenure-mean OPC against the *same trace phase
    # one kernel iteration ago* (like-for-like; content-controlled), falling
    # back to the previous tenure's mean while the phase ring is still filling.
    span_sum = env.span_sum + opc
    span_n = env.span_n + jnp.where(has_ops, 1.0, 0.0)
    cur_mean = span_sum / jnp.maximum(span_n, 1.0)
    slot = env.epochs.astype(jnp.int32) % ctx.t_ring
    ring_ready = (env.epochs >= ctx.t_ring) & has_ops
    ref_sum = env.ref_sum + jnp.where(ring_ready, env.opc_ring[slot], 0.0)
    ref_n = env.ref_n + jnp.where(ring_ready, 1.0, 0.0)
    ref_mean = ref_sum / jnp.maximum(ref_n, 1.0)
    use_ring = ref_n >= span_n - 0.5
    r_ring = compute_reward(cur_mean, ref_mean, deadband=0.01)
    r_prev = jnp.where(env.prev_span_mean >= 0.0,
                       compute_reward(cur_mean, env.prev_span_mean,
                                      deadband=0.01), 0.0)
    reward = jnp.where(invoke,
                       jnp.where(use_ring & (ref_n > 0), r_ring, r_prev), 0.0)
    opc_ring = jnp.where(has_ops, env.opc_ring.at[slot].set(opc), env.opc_ring)

    # ---- EMAs / system info ----
    d = 0.7
    nmp_occ = d * env.nmp_occ + (1 - d) * ops_c
    rb_hit = d * env.rb_hit + (1 - d) * hit_c
    mc_queue = d * env.mc_queue + (1 - d) * mcq
    page_ema = shared.page_ema          # updated in the shared half (PEI only)

    # ---- hot page + page-info cache update (AIMM lanes only) ----
    # The MCs take turns feeding the agent page info (§5.1 round-robin); pages
    # acted on in the last few invocations are skipped so invocations cover the
    # hot set instead of hammering one page.
    if flags.any_aimm:
        touch_cnt = shared.touch_cnt
        recently = jnp.zeros((P,)).at[env.recent_pages].set(
            (env.recent_pages >= 0).astype(jnp.float32))
        hot_page = jnp.argmax(touch_cnt * (1.0 - recently)).astype(jnp.int32)
        touches_hot = touch_cnt[hot_page]
        is_hot_op = ((dest == hot_page) | (src1 == hot_page)
                     | (src2 == hot_page)) & (valid > 0)
        first_hot = jnp.argmax(is_hot_op)
        ccube_hot = ccube[first_hot]
        hops_hot = hops_op[first_hot]

        cache, ent = lookup_or_insert(env.cache, hot_page)
        cache = cache._replace(
            freq=cache.freq.at[ent].add(1.0),
            accesses=cache.accesses.at[ent].add(touches_hot),
            hop_hist=push_hist(cache.hop_hist, ent, hops_hot),
            lat_hist=push_hist(cache.lat_hist, ent, mean_lat),
        )
        env_rng, _k_agent, k_nbr = jax.random.split(env.rng, 3)

        # state vector (paper Fig. 3)
        page_rate = touches_hot / jnp.maximum(3.0 * w_valid, 1.0)
        mig_per_acc = cache.migrations[ent] / jnp.maximum(cache.accesses[ent],
                                                          1.0)
        svec = build_state(
            spec, nmp_occ, rb_hit, mc_queue, env.global_act_hist,
            env.interval_level, page_rate, mig_per_acc,
            cache.hop_hist[ent], cache.lat_hist[ent], cache.mig_hist[ent],
            cache.act_hist[ent], eff_table[hot_page], ccube_hot,
            occ_norm=float(cfg.nmp_table_size),
        )
    else:
        cache, ent = env.cache, jnp.zeros((), jnp.int32)
        hot_page = jnp.zeros((), jnp.int32)
        touches_hot = jnp.zeros(())
        ccube_hot = jnp.zeros((), jnp.int32)
        svec = jnp.zeros((spec.dim,))
        env_rng, k_nbr = env.rng, env.rng

    # ---- TOM control (profiling + commit are action-independent) ----
    if flags.any_tom:
        K = tom_cands.shape[0]
        period = K + TOM_COMMIT_WINDOWS
        phase = (env.epochs.astype(jnp.int32)) % period
        page_live = (jnp.arange(P) < ctx.n_pages).astype(jnp.float32)

        # profiling: candidate `phase` was scored on this window by the epoch
        # driver (under lax.cond on "any lane profiles" — see _epoch_batched);
        # outside profiling phases the scores are unused and may be zeros.
        scores_all = shared.tom_scores
        tom_scores = jnp.where(is_tom & (phase < K),
                               env.tom_scores.at[jnp.clip(phase, 0, K - 1)].set(
                                   scores_all[jnp.clip(phase, 0, K - 1)]),
                               env.tom_scores)
        commit = is_tom & (phase == K)
        best = jnp.argmax(tom_scores).astype(jnp.int32)
        prev_map = jnp.where(env.tom_active >= 0,
                             tom_cands[jnp.maximum(env.tom_active, 0)],
                             env.page_to_cube)
        changed = jnp.sum((tom_cands[best] != prev_map).astype(jnp.float32)
                          * page_live)
        tom_active = jnp.where(commit, best, env.tom_active)
        # remap data movement: amortized one-time link traffic + stall
        mig_stall_tom = jnp.where(commit,
                                  changed * cfg.page_flits / (topo.n_links * 8.0),
                                  0.0)
        migrated_tom = jnp.where(commit, changed, 0.0)
    else:
        tom_scores, tom_active = env.tom_scores, env.tom_active
        mig_stall_tom = jnp.zeros(())
        migrated_tom = jnp.zeros(())

    # ---- energy counters (action-independent part) ----
    en = env.energy
    en = en.at[EN_MEM_BITS].add(w_valid * 3 * cfg.packet_bytes * 8)
    en = en.at[EN_PAGE_CACHE].add(2 * w_valid)
    en = en.at[EN_NMP_BUF].add(2 * w_valid)
    if flags.any_aimm:
        inv = (invoke & is_aimm).astype(jnp.float32)
        if flags.has_agent:
            # One inference + one minibatch (fwd/bwd) per *invocation*: the
            # DQN machinery is invocation-gated, so weight/replay traffic is
            # charged only when the agent actually fires.
            bs = agent_cfg.dqn.batch_size
            en = en.at[EN_WEIGHT].add((1 + 3 * bs) * inv)
            en = en.at[EN_REPLAY].add((1 + bs) * inv)
        en = en.at[EN_STATE_BUF].add(2.0 * inv)

    return EpochMid(
        valid=valid, w_valid=w_valid, has_ops=has_ops, invoke=invoke,
        dest=dest, src1=src1, src2=src2,
        cycles=cycles, opc=opc,
        span_sum=span_sum, span_n=span_n, cur_mean=cur_mean,
        ref_sum=ref_sum, ref_n=ref_n, opc_ring=opc_ring, reward=reward,
        hops_total=hops_total, mean_hops=mean_hops, util=util,
        nmp_occ=nmp_occ, rb_hit=rb_hit, mc_queue=mc_queue,
        page_ema=page_ema, rb_stamp=rb_stamp,
        cache=cache, ent=ent,
        hot_page=hot_page, touches_hot=touches_hot, ccube_hot=ccube_hot,
        svec=svec, k_nbr=k_nbr, env_rng=env_rng,
        tom_scores=tom_scores, tom_active=tom_active,
        mig_stall_tom=mig_stall_tom, migrated_tom=migrated_tom,
        energy=en,
    )


def _tom_window_scores(env: EnvState, trace: dict, tom_cands: jnp.ndarray,
                       ctx: TraceCtx, cfg: NMPConfig,
                       backend: str = "jnp") -> jnp.ndarray:
    """Co-location scores of every TOM candidate mapping on this lane's
    current window: the expensive profiling-phase work, split out of
    `_epoch_sim` so the epoch driver can gate it under `lax.cond` on "any
    lane is in a profiling phase" (the same shape as the DQN invocation
    gate).  Recomputes the window fetch (`_fetch_window`, three slices + the
    mask) — cheap next to scoring K candidates — and is bit-identical to the
    historical inline computation on any backend (the scoring math lives in
    repro.kernels.epoch_fused.ref)."""
    dest, src1, src2, valid = _fetch_window(env, trace, ctx, cfg)
    return epoch_ops.tom_scores(dest, src1, src2, valid, tom_cands,
                                cfg.n_cubes, backend=backend)


# ---------------------------------------------------------------------------
# One epoch: action application + state commit
# ---------------------------------------------------------------------------

def _epoch_apply(env: EnvState, mid: EpochMid, action: jnp.ndarray,
                 rw_pages: jnp.ndarray, ctx: TraceCtx, cfg: NMPConfig,
                 flags: BodyFlags):
    """Apply the chosen action and assemble the next env state + metrics.
    Runs per-lane (vmapped by the epoch driver)."""
    C = cfg.n_cubes
    is_tom = ctx.mapper == MAPPER_ID["tom"]
    is_aimm = ctx.mapper == MAPPER_ID["aimm"]
    invoke, has_ops = mid.invoke, mid.has_ops
    window = jnp.asarray(cfg.epoch_ops, jnp.int32)
    cache = mid.cache
    en = mid.energy

    if flags.any_aimm:
        # --- apply action (no-ops unless an aimm lane at an invocation) ---
        topo = get_topology(cfg)
        hot_page = mid.hot_page
        nbr = act_mod.random_neighbor(mid.k_nbr, mid.ccube_hot,
                                      jnp.asarray(topo.nbr),
                                      jnp.asarray(topo.nbr_valid))
        diag = act_mod.far_target(mid.ccube_hot, jnp.asarray(topo.far))
        is_data = (action == NEAR_DATA) | (action == FAR_DATA)
        is_comp = ((action == NEAR_COMPUTE) | (action == FAR_COMPUTE)
                   | (action == SOURCE_COMPUTE))
        data_tgt = jnp.where(action == NEAR_DATA, nbr, diag)
        comp_tgt = jnp.where(action == NEAR_COMPUTE, nbr,
                             jnp.where(action == FAR_COMPUTE, diag,
                                       jnp.asarray(C, jnp.int32)))

        old_cube = env.page_to_cube[hot_page]
        mig_latency, mig_stall_aimm, mig_loads_aimm = migration_cost(
            old_cube, data_tgt, rw_pages[hot_page], mid.touches_hot, cfg)
        moved = is_data & (data_tgt != old_cube) & invoke & is_aimm
        migrated_aimm = moved.astype(jnp.float32)
        page_to_cube = env.page_to_cube.at[hot_page].set(
            jnp.where(moved, data_tgt, old_cube).astype(jnp.int32))
        mig_latency = jnp.where(moved, mig_latency, 0.0)
        mig_stall_aimm = jnp.where(moved, mig_stall_aimm, 0.0)
        mig_loads_aimm = jnp.where(moved, mig_loads_aimm, 0.0)

        # DEFAULT on the selected page restores its default mapping (clears the
        # compute-remap entry) — gives the agent an undo for stale remaps.
        entry = jnp.where(is_comp, comp_tgt,
                          jnp.where(action == DEFAULT,
                                    jnp.asarray(-1, jnp.int32),
                                    env.compute_remap[hot_page]))
        compute_remap = env.compute_remap.at[hot_page].set(
            jnp.where(invoke & is_aimm, entry,
                      env.compute_remap[hot_page]).astype(jnp.int32))
        # Finite compute-remap table: entries expire after remap_ttl epochs
        # (LRU-style eviction under table pressure) — bounds stale-remap damage.
        remap_age = jnp.where(compute_remap >= 0, env.remap_age + 1, 0)
        expired = remap_age > cfg.remap_ttl
        compute_remap = jnp.where(expired, -1, compute_remap)
        remap_age = jnp.where(expired, 0, remap_age)
        remap_age = jnp.where(is_aimm, remap_age, env.remap_age)
        interval_level = jnp.where(invoke & is_aimm,
                                   act_mod.adjust_interval(env.interval_level,
                                                           action),
                                   env.interval_level)

        cache = cache._replace(
            migrations=cache.migrations.at[mid.ent].add(migrated_aimm),
            mig_hist=jnp.where(moved,
                               push_hist(cache.mig_hist, mid.ent, mig_latency),
                               cache.mig_hist),
            act_hist=jnp.where(invoke & is_aimm,
                               push_hist(cache.act_hist, mid.ent,
                                         action.astype(jnp.float32)),
                               cache.act_hist),
        )
        gah = jnp.where(invoke & is_aimm,
                        jnp.concatenate([env.global_act_hist[1:],
                                         action[None]]),
                        env.global_act_hist)
        recent_pages = jnp.where(invoke & is_aimm,
                                 jnp.concatenate([env.recent_pages[1:],
                                                  hot_page[None]]),
                                 env.recent_pages)
        prev_state_vec = jnp.where(invoke & is_aimm, mid.svec,
                                   env.prev_state_vec)
        prev_action = jnp.where(invoke, action,
                                env.prev_action).astype(jnp.int32)

        # ---- accesses on migrated pages (Fig. 10 stat) ----
        mig_mask = jnp.where(is_aimm,
                             env.mig_page_mask.at[hot_page].set(
                                 jnp.maximum(env.mig_page_mask[hot_page],
                                             migrated_aimm)),
                             env.mig_page_mask)
        acc_mig = (jnp.sum(mig_mask[mid.dest] * mid.valid)
                   + jnp.sum(mig_mask[mid.src1] * mid.valid)
                   + jnp.sum(mig_mask[mid.src2] * mid.valid))

        aimm_f = is_aimm.astype(jnp.float32)
        en = en.at[EN_MIG_Q].add(2 * migrated_aimm * aimm_f)
        en = en.at[EN_MDMA].add(migrated_aimm * cfg.page_flits * aimm_f)
    else:
        page_to_cube = env.page_to_cube
        compute_remap = env.compute_remap
        remap_age = env.remap_age
        interval_level = env.interval_level
        gah = env.global_act_hist
        recent_pages = env.recent_pages
        prev_state_vec = env.prev_state_vec
        prev_action = env.prev_action
        mig_mask = env.mig_page_mask
        acc_mig = jnp.zeros(())
        migrated_aimm = jnp.zeros(())
        mig_stall_aimm = jnp.zeros(())
        mig_loads_aimm = jnp.zeros_like(env.pending_mig_loads)

    # ---- combine mapper outputs ----
    mig_stall = jnp.where(is_aimm, mig_stall_aimm,
                          jnp.where(is_tom, mid.mig_stall_tom, 0.0))
    mig_loads = jnp.where(is_aimm, mig_loads_aimm,
                          jnp.zeros_like(env.pending_mig_loads))
    migrated = jnp.where(is_aimm, migrated_aimm,
                         jnp.where(is_tom, mid.migrated_tom, 0.0))

    en = en.at[EN_NET_BIT_HOPS].add(mid.hops_total * cfg.packet_bytes * 8
                                    + migrated * cfg.page_bytes * 8 * 2)

    cand_env = EnvState(
        page_to_cube=page_to_cube,
        compute_remap=compute_remap,
        op_ptr=env.op_ptr + window,
        interval_level=interval_level,
        since_invoke=jnp.where(invoke, 0,
                               env.since_invoke + 1).astype(jnp.int32),
        span_sum=jnp.where(invoke, 0.0, mid.span_sum),
        span_n=jnp.where(invoke, 0.0, mid.span_n),
        prev_span_mean=jnp.where(invoke, mid.cur_mean, env.prev_span_mean),
        opc_ring=mid.opc_ring,
        ref_sum=jnp.where(invoke, 0.0, mid.ref_sum),
        ref_n=jnp.where(invoke, 0.0, mid.ref_n),
        page_access_ema=mid.page_ema,
        rb_stamp=mid.rb_stamp,
        nmp_occ=mid.nmp_occ,
        rb_hit=mid.rb_hit,
        mc_queue=mid.mc_queue,
        global_act_hist=gah,
        cache=cache,
        pending_mig_loads=mig_loads,
        pending_mig_stall=mig_stall,
        prev_state_vec=prev_state_vec,
        prev_action=prev_action,
        recent_pages=recent_pages,
        remap_age=remap_age,
        rng=mid.env_rng,
        tom_scores=mid.tom_scores,
        tom_active=mid.tom_active,
        cycles=env.cycles + mid.cycles,
        ops_done=env.ops_done + mid.w_valid,
        hops_sum=env.hops_sum + mid.hops_total,
        util_sum=env.util_sum + mid.util,
        epochs=env.epochs + 1.0,
        mig_count=env.mig_count + jnp.where(is_aimm, migrated_aimm, 0.0),
        mig_page_mask=mig_mask,
        access_total=env.access_total + 3 * mid.w_valid,
        access_on_migrated=env.access_on_migrated + acc_mig,
        energy=en,
    )
    # Gate the entire state transition on has_ops: once the (possibly padded)
    # trace is exhausted, every subsequent epoch is an exact no-op, so batched
    # lanes of different lengths stay bit-identical to their serial runs.
    new_env = jax.tree.map(lambda n, o: jnp.where(has_ops, n, o), cand_env, env)
    metrics = {
        "opc": mid.opc, "cycles": mid.cycles, "reward": mid.reward,
        "action": jnp.where(has_ops, action, jnp.zeros((), jnp.int32)),
        "mean_hops": jnp.where(has_ops, mid.mean_hops, 0.0),
        "util": jnp.where(has_ops, mid.util, 0.0),
        "invoke": invoke.astype(jnp.float32), "valid": mid.w_valid,
    }
    return new_env, metrics


# ---------------------------------------------------------------------------
# One epoch: invocation-gated agent step
# ---------------------------------------------------------------------------

def _sel(mask: jnp.ndarray, new, old):
    """Per-lane select over an agent pytree (mask: (B,) bool)."""
    def one(n, o):
        m = mask.reshape(mask.shape + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)
    return jax.tree.map(one, new, old)


def _invoke_agent(agent: AgentState, svec: jnp.ndarray, reward: jnp.ndarray,
                  invoke: jnp.ndarray, prev_svec: jnp.ndarray,
                  prev_action: jnp.ndarray, explore: jnp.ndarray,
                  commit: jnp.ndarray, prev_ok: jnp.ndarray,
                  agent_cfg: AgentConfig, agent_gate: str):
    """Batched continual-learning invocation (Fig. 4-2 flow): the completed
    transition (s_{t-1}, a_{t-1}, r_{t-1}, s_t) enters the replay buffer, the
    DNN takes one minibatch TD step, and ε-greedy inference picks the next
    action.  Every argument carries one flat leading cell axis — the epoch
    driver flattens (lane, seed) grids down to it, so the agent machinery is
    written once for both layouts.

    The TD step sits behind its own nested `lax.cond` on "any committing lane
    has a ready replay buffer": until `min_replay` transitions have
    accumulated, a train step is an exact no-op (masked batch, zero grads
    onto zero Adam moments), so skipping it is bit-identical and the warm-up
    episodes never pay for the minibatch.  The sample RNG is drawn *outside*
    that cond (committing lanes always advance their stream), which is what
    makes the skip exact.  Lanes not committing keep their old agent
    bit-for-bit, so running this under the driver's any-lane-invokes cond
    equals the compute-then-mask reference path (tests/test_engine_golden.py).
    """
    pushed = jax.vmap(agent_mod.observe)(agent, prev_svec, prev_action,
                                         reward, svec)
    ag = _sel(commit & prev_ok, pushed, agent)
    keys = jax.vmap(jax.random.split)(ag.rng)          # (B, 2, key)
    ag = ag._replace(rng=jnp.where(commit[:, None], keys[:, 0], ag.rng))
    k_train = keys[:, 1]

    def do_train(a):
        trained = jax.vmap(lambda al, k: agent_mod.train_step(al, agent_cfg,
                                                              k))(a, k_train)
        return _sel(commit, trained, a)

    ready = agent_mod.replay_ready(ag, agent_cfg)
    if agent_gate == "cond":
        ag = jax.lax.cond(jnp.any(commit & ready), do_train, lambda a: a, ag)
    else:
        ag = do_train(ag)
    action_g, acted = jax.vmap(
        lambda al, s, e: agent_mod.act(al, agent_cfg, s, e))(ag, svec,
                                                             explore)
    ag = _sel(commit, acted, ag)
    action = jnp.where(invoke, action_g,
                       jnp.int32(DEFAULT)).astype(jnp.int32)
    return ag, action


# ---------------------------------------------------------------------------
# Epoch driver + episode runner
# ---------------------------------------------------------------------------

def _epoch_batched(env: EnvState, agent: AgentState | None, trace: dict,
                   rw_pages: jnp.ndarray, tom_cands: jnp.ndarray,
                   ctx: TraceCtx, cfg: NMPConfig, spec: StateSpec,
                   agent_cfg: AgentConfig, flags: BodyFlags,
                   agent_gate: str = "cond", tom_gate: str = "cond",
                   seed_axis: bool = False):
    """One epoch over a (B, ...) batch of lanes.

    With `seed_axis=True` the env (and per-cell EpochMid/metrics) carry a
    (B, S) (lane, seed) grid while the trace / rw_pages / TraceCtx stay
    per-lane (B, ...): the cost-model halves are nested-vmapped with the
    trace axis unmapped over seeds, so S seed replicas of a lane share one
    copy of its (big) trace arrays.  The agent state is kept *flat* over
    B*S cells throughout — only the two cost-model halves need the 2-D view.

    The cost-model halves are vmapped per cell; the agent invocation between
    them is an un-vmapped `lax.cond` on "any lane invokes this epoch"
    (`agent_gate="masked"` forces the compute-every-epoch reference path used
    by the equality test).  TOM's profiling-phase candidate scoring is gated
    the same way: scored only under `lax.cond` on "any lane is in a
    profiling phase" (`tom_gate="masked"` forces the score-every-epoch
    reference path).

    With `flags.share_seed_inv` (seed grids only) the seed-invariant half of
    the cost model is computed once per lane from the seed-0 env slice
    (`_shared_epoch`; every quantity in it evolves identically across seed
    replicas) and broadcast into the inner seed vmap with `in_axes=None` —
    S replicas share one window fetch / stamp scatter / PEI top_k, and TOM's
    profiling scorer runs per lane instead of per cell."""
    share = seed_axis and flags.share_seed_inv
    env0 = jax.tree.map(lambda a: a[:, 0], env) if share else None

    if flags.any_tom:
        K = tom_cands.shape[0]

        def scores_fn(e, t, c):
            return _tom_window_scores(e, t, tom_cands, c, cfg,
                                      flags.epoch_backend)

        score_env = env0 if share else env
        vscores = (jax.vmap(jax.vmap(scores_fn, in_axes=(0, None, None)))
                   if seed_axis and not share else jax.vmap(scores_fn))
        phase = (score_env.epochs.astype(jnp.int32)
                 % (K + TOM_COMMIT_WINDOWS))             # (B,) / (B, S)
        is_tom_b = ctx.mapper == MAPPER_ID["tom"]
        n_ops_b = ctx.n_ops
        if seed_axis and not share:
            is_tom_b, n_ops_b = is_tom_b[:, None], n_ops_b[:, None]
        profiling = is_tom_b & (phase < K) & (score_env.op_ptr < n_ops_b)
        if tom_gate == "cond":
            tom_scores_all = jax.lax.cond(
                jnp.any(profiling),
                lambda: vscores(score_env, trace, ctx),
                lambda: jnp.zeros(phase.shape + (K,)))
        else:
            tom_scores_all = vscores(score_env, trace, ctx)
    else:
        tom_scores_all = None

    def sim_fn(e, t, c, ts):
        return _epoch_sim(e, t, tom_cands, c, cfg, spec, agent_cfg, flags, ts)

    if seed_axis:
        if share:
            shared = jax.vmap(
                lambda e, t, c, ts: _shared_epoch(e, t, c, cfg, flags, ts))(
                    env0, trace, ctx, tom_scores_all)

            def sim_sh(e, t, c, sh):
                return _epoch_sim(e, t, tom_cands, c, cfg, spec, agent_cfg,
                                  flags, shared=sh)

            sim = jax.vmap(jax.vmap(sim_sh, in_axes=(0, None, None, None)))(
                env, trace, ctx, shared)
        else:
            sim = jax.vmap(jax.vmap(sim_fn, in_axes=(0, None, None, 0)))(
                env, trace, ctx, tom_scores_all)
        B, S = sim.invoke.shape
        flat = lambda a: a.reshape((B * S,) + a.shape[2:])
        rep = lambda a: jnp.repeat(a, S, axis=0)         # per-lane -> per-cell
    else:
        sim = jax.vmap(sim_fn)(env, trace, ctx, tom_scores_all)
        flat = rep = lambda a: a

    is_aimm = rep(ctx.mapper == MAPPER_ID["aimm"])       # flat (B*S,)
    forced = rep(ctx.forced_action)
    invoke_f = flat(sim.invoke)
    scripted = jnp.where(invoke_f, forced, jnp.int32(DEFAULT)).astype(jnp.int32)
    if flags.has_agent:
        prev_ok = flat(env.prev_span_mean) >= 0.0
        commit = invoke_f & is_aimm & (forced < 0)

        def fire(ag):
            return _invoke_agent(ag, flat(sim.svec), flat(sim.reward),
                                 invoke_f, flat(env.prev_state_vec),
                                 flat(env.prev_action), rep(ctx.explore),
                                 commit, prev_ok, agent_cfg, agent_gate)

        def hold(ag):
            return ag, jnp.full_like(scripted, DEFAULT)

        if agent_gate == "cond":
            agent, learned = jax.lax.cond(jnp.any(sim.invoke), fire, hold,
                                          agent)
        else:
            agent, learned = fire(agent)
        action = jnp.where(forced >= 0, scripted, learned)
    else:
        action = scripted
    action = jnp.where(is_aimm, action, jnp.zeros_like(action))

    def apply_fn(e, m, a, r, c):
        return _epoch_apply(e, m, a, r, c, cfg, flags)

    if seed_axis:
        env, metrics = jax.vmap(
            jax.vmap(apply_fn, in_axes=(0, 0, 0, None, None)))(
                env, sim, action.reshape(B, S), rw_pages, ctx)
    else:
        env, metrics = jax.vmap(apply_fn)(env, sim, action, rw_pages, ctx)
    return env, agent, metrics


def scan_epochs(trace, rw_pages, env, agent, tom_cands, ctx, cfg, spec,
                agent_cfg, n_epochs, flags, agent_gate="cond",
                tom_gate="cond", seed_axis=False):
    """Un-jitted batched epoch scan shared by the serial and sweep runners.
    All lane-shaped arguments carry a leading (B,) axis (env/agent a (B, S)
    seed grid when `seed_axis` — see _epoch_batched); metrics come back as
    (n_epochs, B[, S])."""
    def body(carry, _):
        env, agent = carry
        env, agent, m = _epoch_batched(env, agent, trace, rw_pages, tom_cands,
                                       ctx, cfg, spec, agent_cfg, flags,
                                       agent_gate, tom_gate, seed_axis)
        return (env, agent), m

    (env, agent), ms = jax.lax.scan(body, (env, agent), None, length=n_epochs)
    return env, agent, ms


@partial(jax.jit, static_argnames=("cfg", "spec", "agent_cfg", "n_epochs",
                                   "flags", "agent_gate", "tom_gate"),
         donate_argnames=("env", "agent"))
def _run_scan(trace, rw_pages, env, agent, tom_cands, ctx, cfg, spec,
              agent_cfg, n_epochs, flags, agent_gate, tom_gate="cond"):
    # env/agent are donated: the scan carry is the same pytree of shapes, so
    # XLA reuses the input buffers for the carry instead of allocating a
    # second stacked-env footprint (the callers build both args fresh).
    return scan_epochs(trace, rw_pages, env, agent, tom_cands, ctx, cfg, spec,
                       agent_cfg, n_epochs, flags, agent_gate, tom_gate)


def state_spec_for(cfg: NMPConfig) -> StateSpec:
    """State layout for a config: cube/MC counts plus the page-info-cache
    history depths (configurable via NMPConfig; the paper's Fig. 3 defaults
    leave the historical layout untouched)."""
    return StateSpec(n_cubes=cfg.n_cubes, n_mcs=cfg.n_mcs,
                     hop_hist=cfg.hop_hist, lat_hist=cfg.lat_hist,
                     mig_hist=cfg.mig_hist, act_hist=cfg.act_hist)


def default_agent_cfg(cfg: NMPConfig) -> AgentConfig:
    """Default AIMM hyperparameters.

    gamma=0: the tenure reward already integrates the action's effect over its
    own horizon (like-for-like vs the previous kernel iteration), so mapping
    control is contextual-bandit-shaped; bootstrapping with large gamma only
    amplified TD noise at these sample counts (see EXPERIMENTS.md §Paper).
    """
    spec = state_spec_for(cfg)
    return AgentConfig(dqn=DQNConfig(state_dim=spec.dim, n_actions=N_ACTIONS,
                                     gamma=0.0))


def pad_trace_ops(trace: Trace, n_total: int, cfg: NMPConfig) -> dict:
    """Trace op arrays padded to `n_total + w_max` (dict of jnp arrays)."""
    pad = n_total - trace.n_ops + cfg.w_max
    return {k: jnp.asarray(np.concatenate([v, np.zeros(pad, v.dtype)]))
            for k, v in trace.as_dict().items() if k != "program_id"}


def _batch1(tree):
    """Add a leading batch axis of 1 to every leaf."""
    return jax.tree.map(lambda a: jnp.asarray(a)[None], tree)


def run_episode(trace: Trace, cfg: NMPConfig = NMPConfig(),
                technique: str = "bnmp", mapper: str = "none",
                agent: AgentState | None = None,
                agent_cfg: AgentConfig | None = None,
                seed: int = 0, page_table: np.ndarray | None = None,
                explore: bool = True, forced_action: int = -1,
                agent_gate: str = "cond",
                tom_gate: str = "cond") -> EpisodeResult:
    """Run one episode (= one pass over the trace) and return final stats.

    `agent` persists across episodes (continual learning); pass the returned
    agent back in to keep training. Env state is reset each episode, matching
    the paper's protocol ("simulation states are cleared except the DNN").
    Cross-scenario persistence (warm starts, program-switch streams,
    checkpointing) lives one layer up in `nmp.continual.PolicyStore` — the
    engine only ever sees an AgentState in, an AgentState out.

    This serial runner is the batched engine at batch size 1 (one vmapped
    lane), so its numbers are bit-identical to the same lane inside a
    `sweep.run_grid` batch by construction.
    """
    assert mapper in MAPPERS and technique in baselines.TECHNIQUES
    spec = state_spec_for(cfg)
    agent_cfg = agent_cfg or default_agent_cfg(cfg)
    flags = episode_flags(trace, cfg, technique, mapper, forced_action)
    if flags.has_agent and agent is None:
        # Fresh lineage: the canonical cold-start convention shared with the
        # sweep's in-jit lane init and the continual layer's fresh tags.
        agent = agent_mod.cold_start(seed, agent_cfg)
    n_epochs = serial_epochs(trace.n_ops, cfg)

    tr = _batch1(pad_trace_ops(trace, trace.n_ops, cfg))
    rw = _batch1(jnp.asarray(trace.read_write))
    pt = page_table if page_table is not None else default_alloc(trace.n_pages, cfg)
    env = _batch1(_init_env(pt, cfg, spec, seed, phase_ring_len(trace, cfg)))
    tom_cands = baselines.tom_candidates(trace.n_pages, cfg)
    ctx = _batch1(make_ctx(trace, cfg, technique, mapper, forced_action,
                           explore))

    env, agent_out, ms = _run_scan(tr, rw, env,
                                   _batch1(agent) if flags.has_agent else None,
                                   tom_cands, ctx, cfg, spec, agent_cfg,
                                   n_epochs, flags, agent_gate, tom_gate)
    env = jax.tree.map(lambda a: a[0], env)
    ms = {k: v[:, 0] for k, v in ms.items()}
    if flags.has_agent:
        agent_out = jax.tree.map(lambda a: a[0], agent_out)
    else:
        agent_out = agent
    return EpisodeResult(env, agent_out, ms)


def run_program(trace: Trace, cfg: NMPConfig = NMPConfig(),
                technique: str = "bnmp", mapper: str = "none",
                episodes: int = 5, seed: int = 0,
                page_table: np.ndarray | None = None,
                agent_cfg: AgentConfig | None = None,
                agent: AgentState | None = None) -> list[EpisodeResult]:
    """Paper §6.1 protocol: run the application episode `episodes` times,
    clearing simulation state between runs but keeping the DNN.

    This is the serial reference runner; `sweep.run_grid` executes the same
    protocol (episode chaining inside one compiled scan) for whole grids.
    """
    results = []
    for e in range(episodes):
        res = run_episode(trace, cfg, technique, mapper, agent=agent,
                          agent_cfg=agent_cfg, seed=seed + e,
                          page_table=page_table)
        agent = res.agent
        results.append(res)
    return results
