"""Trace-driven, epoch-based NMP timing engine.

The entire simulate -> observe -> act -> learn loop is a single `jax.lax.scan`
(one step per agent invocation epoch), so an AIMM run is one compiled XLA
program: the continual-learning agent literally trains inside the simulator.

Epoch model (documented cost model; see DESIGN.md §2):

  window   : the next `window_sizes[interval_level]` ops of the trace
  schedule : technique (BNMP/LDB/PEI) picks a compute cube per op, then the
             AIMM compute-remap table overrides per-page
  route    : packets s1->c, s2->c, c->d over XY routes; per-link flit loads
  time     : cycles = mc_inject + max(compute, link, dram serialization)
             + mean latency + NMP-table overflow stalls + migration stalls
  feedback : OPC = ops/cycles; reward = sign(dOPC); state vector from
             system EMAs + hot-page info cache entry (paper Fig. 3)

Batching model (sweep.py): every per-trace quantity that used to be a Python
static — op count, OPC-ring length, PEI hot-page sort index, technique,
mapper, forced action, exploration flag — is carried as a traced `TraceCtx`
scalar instead, and every state update is gated on `has_ops`, so epochs past
the end of a (padded) trace are exact no-ops. That makes one compiled
program valid for a whole stacked grid of scenarios: `sweep.run_grid` pads
traces to a common envelope and `jax.vmap`s the same epoch body over a
scenario axis, with episode chaining expressed as a `lax.scan`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import actions as act_mod
from repro.core import agent as agent_mod
from repro.core.actions import (DEFAULT, FAR_COMPUTE, FAR_DATA, INC_INTERVAL,
                                DEC_INTERVAL, NEAR_COMPUTE, NEAR_DATA,
                                SOURCE_COMPUTE, N_ACTIONS)
from repro.core.agent import AgentConfig, AgentState
from repro.core.dqn import DQNConfig
from repro.core.reward import compute_reward
from repro.core.state import StateSpec, build_state
from repro.nmp import baselines
from repro.nmp.config import NMPConfig
from repro.nmp.migration import migration_cost
from repro.nmp.network import hop_count, link_loads, n_links, nearest_mc
from repro.nmp.paging import (PageInfoCache, default_alloc, init_page_cache,
                              lookup_or_insert, push_hist)
from repro.nmp.traces import Trace

MAPPERS = ("none", "tom", "aimm")
MAPPER_ID = {m: i for i, m in enumerate(MAPPERS)}
TECH_ID = {t: i for i, t in enumerate(baselines.TECHNIQUES)}

# Energy counter layout (see stats.py).
EN_PAGE_CACHE, EN_NMP_BUF, EN_MIG_Q, EN_MDMA, EN_WEIGHT, EN_REPLAY, \
    EN_STATE_BUF, EN_NET_BIT_HOPS, EN_MEM_BITS, EN_N = range(10)


class TraceCtx(NamedTuple):
    """Per-scenario runtime context: everything that used to be a compile-time
    static but must vary across the lanes of a batched sweep."""
    n_ops: jnp.ndarray          # () i32 real op count (trace arrays may be padded)
    n_pages: jnp.ndarray        # () i32 real page count (tables may be padded)
    t_ring: jnp.ndarray         # () i32 effective OPC phase-ring length
    pei_idx: jnp.ndarray        # () i32 hot-threshold index into the ascending
                                #        sort of the *real* pages' access EMAs
    technique: jnp.ndarray      # () i32 index into baselines.TECHNIQUES
    mapper: jnp.ndarray         # () i32 index into MAPPERS
    forced_action: jnp.ndarray  # () i32 scripted action, -1 = learned policy
    explore: jnp.ndarray        # () bool ε-greedy exploration on/off


def pei_hot_index(n_pages: int, cfg: NMPConfig) -> int:
    """Sort index of the PEI hot-page threshold among the real pages.

    Matches the historical static indexing `sorted[int(P*(1-frac)) - 1]`
    (including Python negative-index wraparound for tiny P).
    """
    return (int(n_pages * (1 - cfg.pei_hot_frac)) - 1) % n_pages


def serial_epochs(n_ops: int, cfg: NMPConfig) -> int:
    return int(np.ceil(n_ops / cfg.epoch_ops)) + 1


def phase_ring_len(trace: Trace, cfg: NMPConfig) -> int:
    """Length of the same-phase OPC reference ring for one trace."""
    iter_ops = trace.iter_ops or trace.n_ops
    n_epochs = serial_epochs(trace.n_ops, cfg)
    return int(np.clip(iter_ops // cfg.epoch_ops, 1, n_epochs + 1))


def make_ctx(trace: Trace, cfg: NMPConfig, technique: str, mapper: str,
             forced_action: int = -1, explore: bool = True) -> TraceCtx:
    assert mapper in MAPPERS and technique in baselines.TECHNIQUES
    return TraceCtx(
        n_ops=jnp.asarray(trace.n_ops, jnp.int32),
        n_pages=jnp.asarray(trace.n_pages, jnp.int32),
        t_ring=jnp.asarray(phase_ring_len(trace, cfg), jnp.int32),
        pei_idx=jnp.asarray(pei_hot_index(trace.n_pages, cfg), jnp.int32),
        technique=jnp.asarray(TECH_ID[technique], jnp.int32),
        mapper=jnp.asarray(MAPPER_ID[mapper], jnp.int32),
        forced_action=jnp.asarray(forced_action, jnp.int32),
        explore=jnp.asarray(explore, bool),
    )


class EnvState(NamedTuple):
    page_to_cube: jnp.ndarray      # (P,) i32 data mapping
    compute_remap: jnp.ndarray     # (P,) i32, -1 = none
    op_ptr: jnp.ndarray            # () i32
    interval_level: jnp.ndarray    # () i32 (stride-1 epochs between invocations)
    since_invoke: jnp.ndarray      # () i32 epochs since last agent invocation
    span_sum: jnp.ndarray          # () f32 OPC sum of current action tenure
    span_n: jnp.ndarray            # () f32
    prev_span_mean: jnp.ndarray    # () f32 (-1 = none yet)
    opc_ring: jnp.ndarray          # (T,) f32 per-phase OPC one iteration ago
    ref_sum: jnp.ndarray           # () f32 same-phase reference sum for tenure
    ref_n: jnp.ndarray             # () f32
    page_access_ema: jnp.ndarray   # (P,) f32
    nmp_occ: jnp.ndarray           # (C,) f32
    rb_hit: jnp.ndarray            # (C,) f32
    mc_queue: jnp.ndarray          # (M,) f32
    global_act_hist: jnp.ndarray   # (Hg,) i32
    cache: PageInfoCache
    pending_mig_loads: jnp.ndarray  # (L,) f32
    pending_mig_stall: jnp.ndarray  # () f32
    prev_state_vec: jnp.ndarray    # (S,) f32
    prev_action: jnp.ndarray       # () i32
    recent_pages: jnp.ndarray      # (R,) i32 pages acted on recently (-1 empty)
    remap_age: jnp.ndarray         # (P,) i32 epochs since compute remap set
    rng: jax.Array
    # TOM state
    tom_scores: jnp.ndarray        # (K,) f32
    tom_active: jnp.ndarray        # () i32 candidate idx in use (-1 = default)
    # cumulative stats
    cycles: jnp.ndarray
    ops_done: jnp.ndarray
    hops_sum: jnp.ndarray
    util_sum: jnp.ndarray
    epochs: jnp.ndarray
    mig_count: jnp.ndarray
    mig_page_mask: jnp.ndarray     # (P,) f32
    access_total: jnp.ndarray
    access_on_migrated: jnp.ndarray
    energy: jnp.ndarray            # (EN_N,) f64-ish counters (f32)


class EpisodeResult(NamedTuple):
    env: EnvState
    agent: AgentState | None
    metrics: dict[str, jnp.ndarray]   # per-epoch stacked


def _init_env(page_table: jnp.ndarray, cfg: NMPConfig, spec: StateSpec,
              seed, t_ring: int = 1) -> EnvState:
    """Fresh env state. `page_table` fixes P (possibly padded); `seed` may be a
    traced scalar (episode scans re-init inside jit); `t_ring` is the static
    ring buffer size (>= every lane's effective TraceCtx.t_ring)."""
    page_table = jnp.asarray(page_table, jnp.int32)
    P = page_table.shape[0]
    C, M = cfg.n_cubes, cfg.n_mcs
    L = n_links(cfg)
    return EnvState(
        page_to_cube=page_table,
        compute_remap=jnp.full((P,), -1, jnp.int32),
        op_ptr=jnp.zeros((), jnp.int32),
        interval_level=jnp.zeros((), jnp.int32),    # invoke every epoch initially
        since_invoke=jnp.zeros((), jnp.int32),
        span_sum=jnp.zeros(()),
        span_n=jnp.zeros(()),
        prev_span_mean=jnp.full((), -1.0),
        opc_ring=jnp.zeros((t_ring,)),
        ref_sum=jnp.zeros(()),
        ref_n=jnp.zeros(()),
        page_access_ema=jnp.zeros((P,)),
        nmp_occ=jnp.zeros((C,)),
        rb_hit=jnp.full((C,), 0.5),
        mc_queue=jnp.zeros((M,)),
        global_act_hist=jnp.zeros((spec.global_act_hist,), jnp.int32),
        cache=init_page_cache(cfg, spec.hop_hist, spec.lat_hist,
                              spec.mig_hist, spec.act_hist),
        pending_mig_loads=jnp.zeros((L,)),
        pending_mig_stall=jnp.zeros(()),
        prev_state_vec=jnp.zeros((spec.dim,)),
        prev_action=jnp.zeros((), jnp.int32),
        recent_pages=jnp.full((max(cfg.recent_ring, 1),), -1, jnp.int32),
        remap_age=jnp.zeros((P,), jnp.int32),
        rng=jax.random.PRNGKey(seed),
        tom_scores=jnp.zeros((6,)),
        tom_active=jnp.full((), -1, jnp.int32),
        cycles=jnp.zeros(()),
        ops_done=jnp.zeros(()),
        hops_sum=jnp.zeros(()),
        util_sum=jnp.zeros(()),
        epochs=jnp.zeros(()),
        mig_count=jnp.zeros(()),
        mig_page_mask=jnp.zeros((P,)),
        access_total=jnp.zeros(()),
        access_on_migrated=jnp.zeros(()),
        energy=jnp.zeros((EN_N,)),
    )


# ---------------------------------------------------------------------------
# One epoch
# ---------------------------------------------------------------------------

def _epoch(env: EnvState, agent: AgentState | None, trace: dict,
           rw_pages: jnp.ndarray, tom_cands: jnp.ndarray, ctx: TraceCtx,
           cfg: NMPConfig, spec: StateSpec, agent_cfg: AgentConfig,
           has_agent: bool):
    """One epoch of the unified engine.

    Technique and mapper are runtime selectors (all paths are computed, the
    lane's path is picked with `where`), so the same compiled body serves any
    scenario lane. Every update is gated on `has_ops` at the end: epochs after
    the trace runs out leave env, agent and metrics untouched, which makes
    op-count padding across a batch exact.
    """
    P = env.page_to_cube.shape[0]
    C = cfg.n_cubes
    W = cfg.w_max
    window = jnp.asarray(cfg.epoch_ops, jnp.int32)
    is_tom = ctx.mapper == MAPPER_ID["tom"]
    is_aimm = ctx.mapper == MAPPER_ID["aimm"]
    page_live = (jnp.arange(P) < ctx.n_pages).astype(jnp.float32)

    # ---- window fetch (trace arrays pre-padded by W) ----
    sl = lambda a: jax.lax.dynamic_slice(a, (env.op_ptr,), (W,))
    dest, src1, src2 = sl(trace["dest"]), sl(trace["src1"]), sl(trace["src2"])
    idx = jnp.arange(W)
    valid = ((idx < window) & (env.op_ptr + idx < ctx.n_ops)).astype(jnp.float32)
    w_valid = valid.sum()
    has_ops = w_valid > 0

    # ---- data mapping (TOM may override the page table) ----
    eff_table = jnp.where(is_tom & (env.tom_active >= 0),
                          tom_cands[jnp.maximum(env.tom_active, 0)],
                          env.page_to_cube)
    dcube = eff_table[dest]
    s1cube = eff_table[src1]
    s2cube = eff_table[src2]

    # ---- schedule compute cube ----
    # PEI hot threshold: padded pages have EMA 0 and sort to the front, so the
    # real-page quantile lives at offset (P - n_pages) + pei_idx.
    sorted_ema = jnp.sort(env.page_access_ema)
    thresh = sorted_ema[(P - ctx.n_pages) + ctx.pei_idx]
    hot1 = env.page_access_ema[src1] >= jnp.maximum(thresh, 1e-6)
    hot2 = env.page_access_ema[src2] >= jnp.maximum(thresh, 1e-6)
    ccube = baselines.schedule_by_id(ctx.technique, dcube, s1cube, s2cube,
                                     hot1, hot2)
    # compute-remap table: -1 none, 0..C-1 fixed cube, C = "source mode"
    # (schedule at the op's own first-source cube, paper action (vi)).
    cr = env.compute_remap[dest]
    cr = jnp.where(cr >= 0, cr, env.compute_remap[src1])
    cr = jnp.where(cr >= 0, cr, env.compute_remap[src2])
    aimm_cc = jnp.where(cr == C, s1cube, jnp.where(cr >= 0, cr, ccube))
    ccube = jnp.where(is_aimm, aimm_cc, ccube)

    # ---- route: flows s1->c, s2->c, c->d (skip zero-hop flows implicitly) ----
    fsrc = jnp.concatenate([s1cube, s2cube, ccube])
    fdst = jnp.concatenate([ccube, ccube, dcube])
    fw = jnp.concatenate([valid, valid, valid]) * cfg.packet_flits
    loads = link_loads(fsrc, fdst, fw, cfg) + env.pending_mig_loads

    hops_op = (hop_count(s1cube, ccube, cfg.mesh_x)
               + hop_count(s2cube, ccube, cfg.mesh_x)
               + hop_count(ccube, dcube, cfg.mesh_x)).astype(jnp.float32)
    hops_total = jnp.sum(hops_op * valid)
    mean_hops = hops_total / jnp.maximum(w_valid, 1.0)

    # ---- per-cube compute load & NMP-table occupancy ----
    ops_c = jnp.zeros((C,)).at[ccube].add(valid)
    table_excess = jnp.maximum(ops_c - cfg.nmp_table_size, 0.0).sum()
    compute_serial = jnp.max(ops_c) * cfg.t_op / cfg.cube_issue_rate
    eff_cubes = jnp.square(ops_c.sum()) / jnp.maximum(jnp.sum(ops_c ** 2), 1.0)
    util = eff_cubes / C

    # ---- row-buffer model: distinct (cube,page) pairs accessed per cube ----
    acc_cube = jnp.concatenate([dcube, s1cube, s2cube])
    acc_page = jnp.concatenate([dest, src1, src2])
    acc_valid = jnp.concatenate([valid, valid, valid])
    key = jnp.where(acc_valid > 0, acc_cube.astype(jnp.int32) * P + acc_page,
                    jnp.int32(C * P + 7))
    skey = jnp.sort(key)
    newrow = jnp.concatenate([jnp.ones((1,), bool), skey[1:] != skey[:-1]])
    newrow = newrow & (skey < C * P)
    sort_cube = (skey // P).astype(jnp.int32)
    distinct_c = jnp.zeros((C,)).at[jnp.clip(sort_cube, 0, C - 1)].add(
        newrow.astype(jnp.float32) * (sort_cube < C))
    acc_c = jnp.zeros((C,)).at[acc_cube].add(acc_valid)
    hit_c = jnp.where(acc_c > 0, 1.0 - distinct_c / jnp.maximum(acc_c, 1.0), 0.5)
    lat_c = hit_c * cfg.t_dram_hit + (1 - hit_c) * cfg.t_dram_miss
    dram_serial = jnp.max(acc_c * lat_c) / (cfg.n_vaults * 4.0)

    # ---- epoch cycles & OPC ----
    mcq = jnp.zeros((cfg.n_mcs,)).at[nearest_mc(cfg)[dcube]].add(valid)
    mc_inject = w_valid / (cfg.n_mcs * cfg.mc_issue_rate)
    # Hottest-link serialization with superlinear queuing amplification: a link
    # loaded far above the network average queues disproportionately (3-stage
    # routers, token flow control), so imbalance costs more than linearly.
    mean_load = jnp.sum(loads) / loads.shape[0]
    imbalance = jnp.max(loads) / jnp.maximum(mean_load, 1.0)
    link_serial = jnp.max(loads) * (1.0 + (cfg.congestion_alpha - 1.0)
                                    * jnp.clip((imbalance - 1.0) / 4.0, 0.0, 1.0))
    mean_lat = (mean_hops * cfg.t_router + cfg.packet_flits
                + jnp.sum(acc_c * lat_c) / jnp.maximum(acc_c.sum(), 1.0))
    # agent invocation cadence: the interval actions control how many epochs an
    # action's tenure lasts (paper intervals {100,125,167,250} cycles, modeled
    # as {1,2,3,4} fixed-size epochs between invocations).
    stride = env.interval_level + 1
    invoke = (env.since_invoke + 1 >= stride) & has_ops
    agent_overhead = jnp.where(is_aimm & invoke, cfg.t_agent, 0.0)
    cycles = (agent_overhead + mc_inject
              + jnp.maximum(jnp.maximum(compute_serial, link_serial), dram_serial)
              + mean_lat + table_excess * cfg.t_op + env.pending_mig_stall)
    cycles = jnp.where(has_ops, cycles, 0.0)
    opc = jnp.where(has_ops, w_valid / jnp.maximum(cycles, 1.0), 0.0)
    # The performance monitor accumulates OPC over the current action's tenure.
    # Reward for the previous action (paper: +-1 on performance improvement or
    # degradation): compare the tenure-mean OPC against the *same trace phase
    # one kernel iteration ago* (like-for-like; content-controlled), falling
    # back to the previous tenure's mean while the phase ring is still filling.
    span_sum = env.span_sum + opc
    span_n = env.span_n + jnp.where(has_ops, 1.0, 0.0)
    cur_mean = span_sum / jnp.maximum(span_n, 1.0)
    slot = env.epochs.astype(jnp.int32) % ctx.t_ring
    ring_ready = (env.epochs >= ctx.t_ring) & has_ops
    ref_sum = env.ref_sum + jnp.where(ring_ready, env.opc_ring[slot], 0.0)
    ref_n = env.ref_n + jnp.where(ring_ready, 1.0, 0.0)
    ref_mean = ref_sum / jnp.maximum(ref_n, 1.0)
    use_ring = ref_n >= span_n - 0.5
    r_ring = compute_reward(cur_mean, ref_mean, deadband=0.01)
    r_prev = jnp.where(env.prev_span_mean >= 0.0,
                       compute_reward(cur_mean, env.prev_span_mean,
                                      deadband=0.01), 0.0)
    reward = jnp.where(invoke,
                       jnp.where(use_ring & (ref_n > 0), r_ring, r_prev), 0.0)
    opc_ring = jnp.where(has_ops, env.opc_ring.at[slot].set(opc), env.opc_ring)

    # ---- EMAs / system info ----
    d = 0.7
    nmp_occ = d * env.nmp_occ + (1 - d) * ops_c
    rb_hit = d * env.rb_hit + (1 - d) * hit_c
    mc_queue = d * env.mc_queue + (1 - d) * mcq
    page_ema = 0.9 * env.page_access_ema
    page_ema = page_ema.at[dest].add(valid).at[src1].add(valid).at[src2].add(valid)

    # ---- hot page + page-info cache update ----
    # The MCs take turns feeding the agent page info (§5.1 round-robin); pages
    # acted on in the last few invocations are skipped so invocations cover the
    # hot set instead of hammering one page.
    touch_cnt = jnp.zeros((P,)).at[dest].add(valid).at[src1].add(valid).at[src2].add(valid)
    recently = jnp.zeros((P,)).at[env.recent_pages].set(
        (env.recent_pages >= 0).astype(jnp.float32))
    hot_page = jnp.argmax(touch_cnt * (1.0 - recently)).astype(jnp.int32)
    touches_hot = touch_cnt[hot_page]
    is_hot_op = ((dest == hot_page) | (src1 == hot_page) | (src2 == hot_page)) & (valid > 0)
    first_hot = jnp.argmax(is_hot_op)
    ccube_hot = ccube[first_hot]
    s1cube_hot = s1cube[first_hot]
    hops_hot = hops_op[first_hot]

    cache, ent = lookup_or_insert(env.cache, hot_page)
    cache = cache._replace(
        freq=cache.freq.at[ent].add(1.0),
        accesses=cache.accesses.at[ent].add(touches_hot),
        hop_hist=push_hist(cache.hop_hist, ent, hops_hot),
        lat_hist=push_hist(cache.lat_hist, ent, mean_lat),
    )

    # ---- AIMM control (computed for every lane; applied where is_aimm) ----
    env_rng, k_agent, k_nbr = jax.random.split(env.rng, 3)
    new_agent = agent

    # state vector (paper Fig. 3)
    page_rate = touches_hot / jnp.maximum(3.0 * w_valid, 1.0)
    mig_per_acc = cache.migrations[ent] / jnp.maximum(cache.accesses[ent], 1.0)
    svec = build_state(
        spec, nmp_occ, rb_hit, mc_queue, env.global_act_hist,
        env.interval_level, page_rate, mig_per_acc,
        cache.hop_hist[ent], cache.lat_hist[ent], cache.mig_hist[ent],
        cache.act_hist[ent], eff_table[hot_page], ccube_hot,
        occ_norm=float(cfg.nmp_table_size),
    )
    # scripted policy (ablations / mechanism-ceiling studies): when
    # ctx.forced_action >= 0, bypass the DQN at every invocation.
    action = jnp.where(invoke, ctx.forced_action, DEFAULT).astype(jnp.int32)
    if has_agent:
        # Fig. 4-2 flow: at an invocation, the completed transition
        # (s_{t-1}, a_{t-1}, r_{t-1}, s_t) enters the replay buffer; the
        # DNN trains continually (every epoch) off the replay buffer.
        sel = lambda new, old: jax.tree.map(
            lambda n, o: jnp.where(invoke & (env.prev_span_mean >= 0), n, o),
            new, old)
        agent_obs = agent_mod.observe(agent, env.prev_state_vec,
                                      env.prev_action, reward, svec)
        agent_full = sel(agent_obs, agent)
        agent_full = agent_mod.train(agent_full, agent_cfg)
        action_g, agent_full = agent_mod.act(agent_full, agent_cfg, svec,
                                             ctx.explore)
        action = jnp.where(ctx.forced_action >= 0, action,
                           jnp.where(invoke, action_g, DEFAULT)).astype(jnp.int32)
        upd = has_ops & is_aimm & (ctx.forced_action < 0)
        new_agent = jax.tree.map(lambda n, o: jnp.where(upd, n, o),
                                 agent_full, agent)
    action = jnp.where(is_aimm, action, jnp.zeros((), jnp.int32))

    # --- apply action (no-ops unless an aimm lane at an invocation) ---
    nbr = act_mod.random_neighbor(k_nbr, ccube_hot, cfg.mesh_x, cfg.mesh_y)
    diag = act_mod.diagonal_opposite(ccube_hot, cfg.mesh_x, cfg.mesh_y)
    is_data = (action == NEAR_DATA) | (action == FAR_DATA)
    is_comp = ((action == NEAR_COMPUTE) | (action == FAR_COMPUTE)
               | (action == SOURCE_COMPUTE))
    data_tgt = jnp.where(action == NEAR_DATA, nbr, diag)
    comp_tgt = jnp.where(action == NEAR_COMPUTE, nbr,
                         jnp.where(action == FAR_COMPUTE, diag,
                                   jnp.asarray(C, jnp.int32)))

    old_cube = env.page_to_cube[hot_page]
    mig_latency, mig_stall_aimm, mig_loads_aimm = migration_cost(
        old_cube, data_tgt, rw_pages[hot_page], touches_hot, cfg)
    moved = is_data & (data_tgt != old_cube) & invoke & is_aimm
    migrated_aimm = moved.astype(jnp.float32)
    page_to_cube = env.page_to_cube.at[hot_page].set(
        jnp.where(moved, data_tgt, old_cube).astype(jnp.int32))
    mig_latency = jnp.where(moved, mig_latency, 0.0)
    mig_stall_aimm = jnp.where(moved, mig_stall_aimm, 0.0)
    mig_loads_aimm = jnp.where(moved, mig_loads_aimm, 0.0)

    # DEFAULT on the selected page restores its default mapping (clears the
    # compute-remap entry) — gives the agent an undo for stale remaps.
    entry = jnp.where(is_comp, comp_tgt,
                      jnp.where(action == DEFAULT,
                                jnp.asarray(-1, jnp.int32),
                                env.compute_remap[hot_page]))
    compute_remap = env.compute_remap.at[hot_page].set(
        jnp.where(invoke & is_aimm, entry,
                  env.compute_remap[hot_page]).astype(jnp.int32))
    # Finite compute-remap table: entries expire after remap_ttl epochs
    # (LRU-style eviction under table pressure) — bounds stale-remap damage.
    remap_age = jnp.where(compute_remap >= 0, env.remap_age + 1, 0)
    expired = remap_age > cfg.remap_ttl
    compute_remap = jnp.where(expired, -1, compute_remap)
    remap_age = jnp.where(expired, 0, remap_age)
    interval_level = jnp.where(invoke & is_aimm,
                               act_mod.adjust_interval(env.interval_level,
                                                       action),
                               env.interval_level)

    cache = cache._replace(
        migrations=cache.migrations.at[ent].add(migrated_aimm),
        mig_hist=jnp.where(moved,
                           push_hist(cache.mig_hist, ent, mig_latency),
                           cache.mig_hist),
        act_hist=jnp.where(invoke & is_aimm,
                           push_hist(cache.act_hist, ent,
                                     action.astype(jnp.float32)),
                           cache.act_hist),
    )
    gah = jnp.where(invoke & is_aimm,
                    jnp.concatenate([env.global_act_hist[1:], action[None]]),
                    env.global_act_hist)

    # ---- TOM control (computed for every lane; applied where is_tom) ----
    K = tom_cands.shape[0]
    period = K + 8                 # K profiling windows + 8 commit windows
    phase = (env.epochs.astype(jnp.int32)) % period
    # profiling: evaluate candidate `phase` on this window
    def score_k(k):
        return baselines.tom_colocation_score(tom_cands[k], dest, src1,
                                              src2, valid, C)
    scores_all = jax.vmap(score_k)(jnp.arange(K))
    tom_scores = jnp.where(is_tom & (phase < K),
                           env.tom_scores.at[jnp.clip(phase, 0, K - 1)].set(
                               scores_all[jnp.clip(phase, 0, K - 1)]),
                           env.tom_scores)
    commit = is_tom & (phase == K)
    best = jnp.argmax(tom_scores).astype(jnp.int32)
    prev_map = jnp.where(env.tom_active >= 0,
                         tom_cands[jnp.maximum(env.tom_active, 0)],
                         env.page_to_cube)
    changed = jnp.sum((tom_cands[best] != prev_map).astype(jnp.float32)
                      * page_live)
    tom_active = jnp.where(commit, best, env.tom_active)
    # remap data movement: amortized one-time link traffic + stall
    mig_stall_tom = jnp.where(commit,
                              changed * cfg.page_flits / (n_links(cfg) * 8.0),
                              0.0)
    migrated_tom = jnp.where(commit, changed, 0.0)

    # ---- combine mapper outputs ----
    mig_stall = jnp.where(is_aimm, mig_stall_aimm,
                          jnp.where(is_tom, mig_stall_tom, 0.0))
    mig_loads = jnp.where(is_aimm, mig_loads_aimm,
                          jnp.zeros_like(env.pending_mig_loads))
    migrated = jnp.where(is_aimm, migrated_aimm,
                         jnp.where(is_tom, migrated_tom, 0.0))

    # ---- accesses on migrated pages (Fig. 10 stat) ----
    mig_mask = jnp.where(is_aimm,
                         env.mig_page_mask.at[hot_page].set(
                             jnp.maximum(env.mig_page_mask[hot_page],
                                         migrated_aimm)),
                         env.mig_page_mask)
    acc_mig = (jnp.sum(mig_mask[dest] * valid) + jnp.sum(mig_mask[src1] * valid)
               + jnp.sum(mig_mask[src2] * valid))

    # ---- energy counters ----
    aimm_f = is_aimm.astype(jnp.float32)
    en = env.energy
    en = en.at[EN_MEM_BITS].add(w_valid * 3 * cfg.packet_bytes * 8)
    en = en.at[EN_NET_BIT_HOPS].add(hops_total * cfg.packet_bytes * 8
                                    + migrated * cfg.page_bytes * 8 * 2)
    en = en.at[EN_PAGE_CACHE].add(2 * w_valid)
    en = en.at[EN_NMP_BUF].add(2 * w_valid)
    bs = agent_cfg.dqn.batch_size
    inv = (invoke & is_aimm).astype(jnp.float32)
    en = en.at[EN_MIG_Q].add(2 * migrated_aimm * aimm_f)
    en = en.at[EN_MDMA].add(migrated_aimm * cfg.page_flits * aimm_f)
    en = en.at[EN_WEIGHT].add((inv + 3 * bs) * aimm_f)  # inference + fwd/bwd batch
    en = en.at[EN_REPLAY].add((inv + bs) * aimm_f)
    en = en.at[EN_STATE_BUF].add(2.0 * inv)

    cand_env = EnvState(
        page_to_cube=page_to_cube,
        compute_remap=compute_remap,
        op_ptr=env.op_ptr + window,
        interval_level=interval_level,
        since_invoke=jnp.where(invoke, 0,
                               env.since_invoke + 1).astype(jnp.int32),
        span_sum=jnp.where(invoke, 0.0, span_sum),
        span_n=jnp.where(invoke, 0.0, span_n),
        prev_span_mean=jnp.where(invoke, cur_mean, env.prev_span_mean),
        opc_ring=opc_ring,
        ref_sum=jnp.where(invoke, 0.0, ref_sum),
        ref_n=jnp.where(invoke, 0.0, ref_n),
        page_access_ema=page_ema,
        nmp_occ=nmp_occ,
        rb_hit=rb_hit,
        mc_queue=mc_queue,
        global_act_hist=gah,
        cache=cache,
        pending_mig_loads=mig_loads,
        pending_mig_stall=mig_stall,
        prev_state_vec=jnp.where(invoke & is_aimm, svec, env.prev_state_vec),
        prev_action=jnp.where(invoke, action, env.prev_action).astype(jnp.int32),
        recent_pages=jnp.where(invoke & is_aimm,
                               jnp.concatenate([env.recent_pages[1:],
                                                hot_page[None]]),
                               env.recent_pages),
        remap_age=jnp.where(is_aimm, remap_age, env.remap_age),
        rng=env_rng,
        tom_scores=tom_scores,
        tom_active=tom_active,
        cycles=env.cycles + cycles,
        ops_done=env.ops_done + w_valid,
        hops_sum=env.hops_sum + hops_total,
        util_sum=env.util_sum + util,
        epochs=env.epochs + 1.0,
        mig_count=env.mig_count + jnp.where(is_aimm, migrated_aimm, 0.0),
        mig_page_mask=mig_mask,
        access_total=env.access_total + 3 * w_valid,
        access_on_migrated=env.access_on_migrated + acc_mig,
        energy=en,
    )
    # Gate the entire state transition on has_ops: once the (possibly padded)
    # trace is exhausted, every subsequent epoch is an exact no-op, so batched
    # lanes of different lengths stay bit-identical to their serial runs.
    new_env = jax.tree.map(lambda n, o: jnp.where(has_ops, n, o), cand_env, env)
    metrics = {
        "opc": opc, "cycles": cycles, "reward": reward,
        "action": jnp.where(has_ops, action, jnp.zeros((), jnp.int32)),
        "mean_hops": jnp.where(has_ops, mean_hops, 0.0),
        "util": jnp.where(has_ops, util, 0.0),
        "invoke": invoke.astype(jnp.float32), "valid": w_valid,
    }
    return new_env, new_agent, metrics


# ---------------------------------------------------------------------------
# Episode runner
# ---------------------------------------------------------------------------

def scan_epochs(trace, rw_pages, env, agent, tom_cands, ctx, cfg, spec,
                agent_cfg, n_epochs, has_agent):
    """Un-jitted epoch scan shared by the serial and batched runners."""
    def body(carry, _):
        env, agent = carry
        env, agent, m = _epoch(env, agent, trace, rw_pages, tom_cands, ctx,
                               cfg, spec, agent_cfg, has_agent)
        return (env, agent), m

    (env, agent), ms = jax.lax.scan(body, (env, agent), None, length=n_epochs)
    return env, agent, ms


@partial(jax.jit, static_argnames=("cfg", "spec", "agent_cfg", "n_epochs",
                                   "has_agent"))
def _run_scan(trace, rw_pages, env, agent, tom_cands, ctx, cfg, spec,
              agent_cfg, n_epochs, has_agent):
    return scan_epochs(trace, rw_pages, env, agent, tom_cands, ctx, cfg, spec,
                       agent_cfg, n_epochs, has_agent)


def state_spec_for(cfg: NMPConfig) -> StateSpec:
    return StateSpec(n_cubes=cfg.n_cubes, n_mcs=cfg.n_mcs)


def default_agent_cfg(cfg: NMPConfig) -> AgentConfig:
    """Default AIMM hyperparameters.

    gamma=0: the tenure reward already integrates the action's effect over its
    own horizon (like-for-like vs the previous kernel iteration), so mapping
    control is contextual-bandit-shaped; bootstrapping with large gamma only
    amplified TD noise at these sample counts (see EXPERIMENTS.md §Paper).
    """
    spec = state_spec_for(cfg)
    return AgentConfig(dqn=DQNConfig(state_dim=spec.dim, n_actions=N_ACTIONS,
                                     gamma=0.0))


def pad_trace_ops(trace: Trace, n_total: int, cfg: NMPConfig) -> dict:
    """Trace op arrays padded to `n_total + w_max` (dict of jnp arrays)."""
    pad = n_total - trace.n_ops + cfg.w_max
    return {k: jnp.asarray(np.concatenate([v, np.zeros(pad, v.dtype)]))
            for k, v in trace.as_dict().items() if k != "program_id"}


def run_episode(trace: Trace, cfg: NMPConfig = NMPConfig(),
                technique: str = "bnmp", mapper: str = "none",
                agent: AgentState | None = None,
                agent_cfg: AgentConfig | None = None,
                seed: int = 0, page_table: np.ndarray | None = None,
                explore: bool = True, forced_action: int = -1) -> EpisodeResult:
    """Run one episode (= one pass over the trace) and return final stats.

    `agent` persists across episodes (continual learning); pass the returned
    agent back in to keep training. Env state is reset each episode, matching
    the paper's protocol ("simulation states are cleared except the DNN").
    """
    assert mapper in MAPPERS and technique in baselines.TECHNIQUES
    spec = state_spec_for(cfg)
    agent_cfg = agent_cfg or default_agent_cfg(cfg)
    has_agent = mapper == "aimm" and forced_action < 0
    if has_agent and agent is None:
        agent = agent_mod.init_agent(jax.random.PRNGKey(seed + 1), agent_cfg)
    n_epochs = serial_epochs(trace.n_ops, cfg)

    tr = pad_trace_ops(trace, trace.n_ops, cfg)
    rw = jnp.asarray(trace.read_write)
    pt = page_table if page_table is not None else default_alloc(trace.n_pages, cfg)
    env = _init_env(pt, cfg, spec, seed, phase_ring_len(trace, cfg))
    tom_cands = baselines.tom_candidates(trace.n_pages, cfg)
    ctx = make_ctx(trace, cfg, technique, mapper, forced_action, explore)

    env, agent_out, ms = _run_scan(tr, rw, env, agent if has_agent else None,
                                   tom_cands, ctx, cfg, spec, agent_cfg,
                                   n_epochs, has_agent)
    return EpisodeResult(env, agent_out if has_agent else agent, ms)


def run_program(trace: Trace, cfg: NMPConfig = NMPConfig(),
                technique: str = "bnmp", mapper: str = "none",
                episodes: int = 5, seed: int = 0,
                page_table: np.ndarray | None = None,
                agent_cfg: AgentConfig | None = None,
                agent: AgentState | None = None) -> list[EpisodeResult]:
    """Paper §6.1 protocol: run the application episode `episodes` times,
    clearing simulation state between runs but keeping the DNN.

    This is the serial reference runner; `sweep.run_grid` executes the same
    protocol (episode chaining inside one compiled scan) for whole grids.
    """
    results = []
    for e in range(episodes):
        res = run_episode(trace, cfg, technique, mapper, agent=agent,
                          agent_cfg=agent_cfg, seed=seed + e,
                          page_table=page_table)
        agent = res.agent
        results.append(res)
    return results
