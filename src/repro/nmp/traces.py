"""Synthetic NMP-op trace generators for the paper's nine kernels (§6.4-6.5).

The paper replays `<&dest += &src1 OP &src2>` traces captured from annotated
NMP regions of Rodinia/CRONO/CortexSuite kernels. Offline we synthesize traces
whose *measured* characteristics reproduce the paper's workload analysis:

  Fig. 5a  page-access-volume classes (low / moderate / heavy),
  Fig. 5b  active pages per epoch (working set),
  Fig. 5c  page affinity (radix x co-access weight quadrants).

The paper targets "long running applications ... which repeatedly use their
kernels": each generator builds one kernel-iteration access pattern and tiles
it `iters` times (with per-iteration jitter where the real kernel would not be
exactly periodic), so runtime remapping decisions can pay off on later
iterations — the effect AIMM exploits.

`tests/test_traces.py` asserts the §6.5 characteristics per kernel.
"""
from __future__ import annotations

import dataclasses

import numpy as np

APPS = ("BP", "LUD", "KM", "MAC", "PR", "RBM", "RD", "SC", "SPMV")


@dataclasses.dataclass
class Trace:
    name: str
    dest: np.ndarray       # (n_ops,) int32 page ids
    src1: np.ndarray
    src2: np.ndarray
    n_pages: int
    read_write: np.ndarray  # (n_pages,) bool: True => RW page (blocking migration)
    program_id: np.ndarray  # (n_ops,) int32 (0 for single-program)
    iter_ops: int = 0       # ops per kernel iteration (0 = non-periodic)

    @property
    def n_ops(self) -> int:
        return int(self.dest.shape[0])

    def as_dict(self):
        return {
            "dest": self.dest, "src1": self.src1, "src2": self.src2,
            "program_id": self.program_id,
        }


def _mk(name, dest, src1, src2, n_pages, rw_pages=None, iter_ops=0):
    dest = np.asarray(dest, np.int32)
    src1 = np.asarray(src1, np.int32)
    src2 = np.asarray(src2, np.int32)
    rw = np.zeros(n_pages, bool)
    rw[np.unique(dest)] = True           # destination pages are read-write
    if rw_pages is not None:
        rw[rw_pages] = True
    return Trace(name, dest, src1, src2, n_pages,
                 rw, np.zeros_like(dest), iter_ops)


def _tile(pattern: tuple[np.ndarray, np.ndarray, np.ndarray], n_ops: int):
    """Repeat one kernel-iteration pattern up to n_ops ops."""
    d, a, b = (np.asarray(x, np.int32) for x in pattern)
    reps = int(np.ceil(n_ops / d.size))
    return (np.tile(d, reps)[:n_ops], np.tile(a, reps)[:n_ops],
            np.tile(b, reps)[:n_ops])


def _zipf(rng, n, size, alpha):
    p = 1.0 / np.arange(1, n + 1) ** alpha
    p /= p.sum()
    return rng.choice(n, size=size, p=p)


def backprop(n_ops=8192, seed=0, iters=4) -> Trace:
    """BP: huge memory residency, small working set, low affinity/page reuse.

    One training epoch sweeps a large weight region once (weight-gradient
    accumulation) against a small hot activation set; epochs repeat.
    """
    rng = np.random.default_rng(seed)
    n_pages = 4096
    n_act = 64                                   # hot activation pages
    per = n_ops // iters
    weights = rng.permutation(n_pages - n_act)[:per] + n_act
    dest = weights                               # sweep weights (low reuse)
    src1 = rng.integers(0, n_act, per)           # activations (hot)
    src2 = np.clip(dest - 1, 0, n_pages - 1)
    return _mk("BP", *_tile((dest, src1, src2), n_ops), n_pages, iter_ops=per)


def lud(n_ops=8192, seed=1, iters=1) -> Trace:
    """LUD: blocked factorization — high active pages, high affinity.

    The k-loop itself revisits row/column panels, so no extra tiling needed.
    """
    rng = np.random.default_rng(seed)
    nb = 32                                      # blocks per matrix dim
    n_pages = nb * nb
    dest, src1, src2 = [], [], []
    k = 0
    while len(dest) < n_ops:
        k = (k + 1) % (nb - 1)
        # trailing submatrix update: A[i,j] -= A[i,k] * A[k,j]
        ii = rng.integers(k + 1, nb, size=min(256, n_ops - len(dest)))
        jj = rng.integers(k + 1, nb, size=ii.size)
        dest.extend(ii * nb + jj)
        src1.extend(ii * nb + k)
        src2.extend(k * nb + jj)
    return _mk("LUD", dest[:n_ops], src1[:n_ops], src2[:n_ops], n_pages)


def kmeans(n_ops=8192, seed=2, iters=4) -> Trace:
    """KM: centroid pages extremely hot; points re-streamed every iteration."""
    rng = np.random.default_rng(seed)
    n_pages = 512
    k = 16
    per = n_ops // iters
    pts = rng.integers(k, n_pages, per)
    cent = rng.integers(0, k, per)
    return _mk("KM", *_tile((cent, pts, cent), n_ops), n_pages, iter_ops=per)


def mac(n_ops=8192, seed=3, iters=2) -> Trace:
    """MAC: multiply-accumulate over two sequential vectors; streaming, low reuse."""
    n_pages = 1024
    v = n_pages // 2 - 8
    per = n_ops // iters
    i = np.arange(per)
    src1 = 8 + (i * 7919) % v            # strided walk over vector A region
    src2 = 8 + v + (i * 7919) % v        # matching walk over vector B
    dest = (i // 64) % 8                 # few accumulator pages (hot dests)
    return _mk("MAC", *_tile((dest, src1, src2), n_ops), n_pages, iter_ops=per)


def pagerank(n_ops=16384, seed=4, iters=4) -> Trace:
    """PR: power-law graph; rank iterations repeat the edge list (large WS,
    high radix, many lightly-accessed pages)."""
    rng = np.random.default_rng(seed)
    n_pages = 2048
    per = n_ops // iters
    dst_nodes = _zipf(rng, n_pages, per, alpha=1.1)   # rank[dst] += rank[src]/deg
    src_nodes = _zipf(rng, n_pages, per, alpha=0.7)
    deg = rng.integers(0, n_pages, per)               # degree table access
    return _mk("PR", *_tile((dst_nodes, src_nodes, deg), n_ops), n_pages, iter_ops=per)


def rbm(n_ops=8192, seed=5, iters=8) -> Trace:
    """RBM: bipartite visible/hidden — tiny page set, nearly all active, high
    affinity, heavy reuse across contrastive-divergence epochs."""
    rng = np.random.default_rng(seed)
    n_pages = 96
    nv = 48
    per = n_ops // iters
    hid = rng.integers(nv, n_pages, per)
    vis = rng.integers(0, nv, per)
    w = rng.integers(0, n_pages, per)
    return _mk("RBM", *_tile((hid, vis, w), n_ops), n_pages, iter_ops=per)


def reduce_(n_ops=8192, seed=6, iters=2) -> Trace:
    """RD: sum reduction over a sequential vector; very low reuse."""
    n_pages = 1024
    per = n_ops // iters
    i = np.arange(per)
    src1 = 4 + i % (n_pages - 4)
    src2 = 4 + (i + 1) % (n_pages - 4)
    dest = i % 4                               # accumulator tree root pages
    return _mk("RD", *_tile((dest, src1, src2), n_ops), n_pages, iter_ops=per)


def streamcluster(n_ops=8192, seed=7, iters=4) -> Trace:
    """SC: stream points vs medium-sized center set (user-determined WS)."""
    rng = np.random.default_rng(seed)
    n_pages = 768
    n_centers = 96
    per = n_ops // iters
    centers = rng.integers(0, n_centers, per)
    pts = (np.arange(per) * 13) % (n_pages - n_centers) + n_centers
    return _mk("SC", *_tile((centers, pts, centers), n_ops), n_pages, iter_ops=per)


def spmv(n_ops=8192, seed=8, iters=4) -> Trace:
    """SPMV: iterative solver — irregular column gathers, ~10 active pages per
    window, same matrix re-multiplied every iteration."""
    rng = np.random.default_rng(seed)
    n_pages = 1024
    n_rows = 64                                # output vector pages
    per = n_ops // iters
    row_of_op = np.repeat(np.arange(per // 32 + 1) % n_rows, 32)[:per]
    cols = _zipf(rng, n_pages - n_rows, per, alpha=0.9) + n_rows
    x = _zipf(rng, n_pages - n_rows, per, alpha=1.2) + n_rows
    return _mk("SPMV", *_tile((row_of_op, cols, x), n_ops), n_pages, iter_ops=per)


_GENERATORS = {
    "BP": backprop, "LUD": lud, "KM": kmeans, "MAC": mac, "PR": pagerank,
    "RBM": rbm, "RD": reduce_, "SC": streamcluster, "SPMV": spmv,
}


def make_trace(app: str, n_ops: int = 8192, seed: int | None = None,
               **kw) -> Trace:
    gen = _GENERATORS[app.upper()]
    kw["n_ops"] = n_ops
    if seed is not None:
        kw["seed"] = seed
    return gen(**kw)


def merge_traces(traces: list[Trace], interleave: int = 32) -> Trace:
    """Multi-program workload: interleave traces round-robin in `interleave`-op
    bursts with disjoint (offset) page spaces, as in the paper's shared-resource
    baseline (§7.5.2)."""
    offsets = np.cumsum([0] + [t.n_pages for t in traces[:-1]])
    n_pages = sum(t.n_pages for t in traces)
    streams = []
    for pid, (t, off) in enumerate(zip(traces, offsets)):
        streams.append({
            "dest": t.dest + off, "src1": t.src1 + off, "src2": t.src2 + off,
            "program_id": np.full(t.n_ops, pid, np.int32),
        })
    n_total = sum(t.n_ops for t in traces)
    cols = {k: np.zeros(n_total, np.int32) for k in ("dest", "src1", "src2", "program_id")}
    ptrs = [0] * len(traces)
    pos = 0
    while pos < n_total:
        for pid, t in enumerate(traces):
            take = min(interleave, t.n_ops - ptrs[pid], n_total - pos)
            if take <= 0:
                continue
            for k in cols:
                cols[k][pos:pos + take] = streams[pid][k][ptrs[pid]:ptrs[pid] + take]
            ptrs[pid] += take
            pos += take
    rw = np.zeros(n_pages, bool)
    for t, off in zip(traces, offsets):
        rw[off:off + t.n_pages] = t.read_write
    name = "+".join(t.name for t in traces)
    iter_ops = sum(t.iter_ops or t.n_ops for t in traces)
    return Trace(name, cols["dest"], cols["src1"], cols["src2"], n_pages, rw,
                 cols["program_id"], iter_ops)


def program_of_page(trace: Trace) -> np.ndarray:
    """Recover page->program ownership (for the HOARD allocator)."""
    owner = np.zeros(trace.n_pages, np.int32)
    for arr in (trace.dest, trace.src1, trace.src2):
        owner[arr] = trace.program_id
    return owner


# ---------------------------------------------------------------------------
# Workload analysis (reproduces Fig. 5)
# ---------------------------------------------------------------------------

def analyze(trace: Trace, epoch: int = 250) -> dict:
    """Page-access classes, active pages per epoch, affinity quadrants."""
    pages = np.concatenate([trace.dest, trace.src1, trace.src2])
    counts = np.bincount(pages, minlength=trace.n_pages)
    used = counts[counts > 0]
    q1, q2 = np.quantile(used, [0.5, 0.9]) if used.size else (0, 0)
    classes = {
        "low": float((used <= max(q1, 2)).mean()) if used.size else 0.0,
        "moderate": float(((used > max(q1, 2)) & (used <= q2)).mean()) if used.size else 0.0,
        "heavy": float((used > q2).mean()) if used.size else 0.0,
    }
    n_epochs = max(trace.n_ops // epoch, 1)
    active = []
    for e in range(n_epochs):
        w = slice(e * epoch, (e + 1) * epoch)
        active.append(len(np.unique(np.concatenate(
            [trace.dest[w], trace.src1[w], trace.src2[w]]))))
    # affinity: radix = distinct partner pages; weight = co-access count
    pairs = np.stack([
        np.concatenate([trace.dest, trace.dest, trace.src1]),
        np.concatenate([trace.src1, trace.src2, trace.src2]),
    ], 1)
    key = pairs[:, 0].astype(np.int64) * trace.n_pages + pairs[:, 1]
    uniq, wcnt = np.unique(key, return_counts=True)
    a = uniq // trace.n_pages
    radix = np.bincount(a.astype(np.int64), minlength=trace.n_pages)
    return {
        "classes": classes,
        "active_pages_mean": float(np.mean(active)),
        "radix_mean": float(radix[radix > 0].mean()) if (radix > 0).any() else 0.0,
        "edge_weight_mean": float(wcnt.mean()) if wcnt.size else 0.0,
        "n_pages_used": int((counts > 0).sum()),
    }
