"""Page migration model (paper §5.3).

A data-remap decision enqueues (page, new_cube) into the migration system.
The MDMA streams the 4 KB frame over the topology's precomputed route
old->new (XY on the paper's mesh; minimal routes elsewhere — see
nmp.topology):

  * traffic   : page_flits x hops, charged to the link-load histogram of the
                following epoch (migration shares the memory network),
  * latency   : DMA serialization + per-hop routing, reported back to the MC
                and recorded in the page's migration-latency history,
  * blocking  : RW pages are locked during migration (coherence) — ops touching
                the page in-flight stall; RO pages migrate non-blocking with
                only a residual cost (old frame serves reads until drained).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.nmp.config import NMPConfig
from repro.nmp.topology import get_topology


def migration_cost(old_cube: jnp.ndarray, new_cube: jnp.ndarray,
                   is_rw: jnp.ndarray, touches: jnp.ndarray,
                   cfg: NMPConfig) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Cost of migrating one page.

    touches: number of window ops touching the page while it migrates.
    Returns (latency_cycles, stall_cycles, link_load_vector).  An exact
    no-op when old_cube == new_cube: zero latency, zero stall, zero loads
    (the route incidence row of a self-route is empty on every topology).
    """
    topo = get_topology(cfg)
    hops = jnp.asarray(topo.hops)[old_cube, new_cube].astype(jnp.float32)
    moving = (hops > 0).astype(jnp.float32)
    latency = moving * (cfg.page_flits + hops * cfg.t_router + cfg.t_page_walk)
    # Blocked accesses overlap the DMA; the epoch-level stall is a fraction of
    # the DMA duration (blocking >> non-blocking, which only pays an old-frame
    # drain residual).
    stall_frac = jnp.where(is_rw, 0.25, 0.05)
    stall = moving * (stall_frac * latency
                      + 4.0 * jnp.minimum(touches.astype(jnp.float32), 8.0))
    # DMA traffic over the precomputed route: the page's flits on every link
    # of the old->new path, from one gather of the incidence tensor.
    loads = (jnp.asarray(topo.route_links)[old_cube, new_cube]
             * cfg.page_flits * moving)
    return latency, stall, loads
