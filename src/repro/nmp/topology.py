"""Pluggable memory-cube topology layer: precomputed routing tensors.

The engine's cost model never routes packets at run time.  A `Topology` is
built host-side once per (topology name, geometry) and precomputes every
dense tensor the epoch body needs, so routing collapses to gathers and one
einsum that are *topology-agnostic*:

  hops        (C, C)    i32   path length (link traversals) of route s->d
  route_links (C, C, L) f32   0/1 incidence: link l lies on route s->d
  nearest_mc  (C,)      i32   cube -> nearest memory controller index
  nbr/nbr_valid (C, D)        neighbor table for the paper's "near" remap
                              actions (D = max degree; invalid slots = self)
  far         (C,)      i32   "far" remap target per cube

`link_loads` is then `einsum("f,fl->l", w, route_links[src, dst])` — one
gather + einsum regardless of interconnect — and `hop_count` a pure gather.
Because route weights are exact small binaries (packet/page flit counts),
the einsum is bit-exact under any reduction order, which is what lets the
`mesh2d` builder reproduce the historical XY-routing model bit-for-bit
(tests/test_engine_golden.py pins it).

Builders:

  mesh2d    : the paper's 2D mesh with static XY routing.  Link ids, the
              neighbor slot order and the mirror-diagonal far table match
              the historical `nmp.network` / `core.actions` model exactly.
  torus2d   : 2D torus (wraparound X/Y rings); BFS minimal routes.
  ring      : single bidirectional ring over all cubes.
  dragonfly : groups of `mesh_x` cubes, all-to-all inside a group, one
              global link per group pair (attached round-robin over the
              group's cubes); minimal group-direct routes via BFS.

Every builder satisfies the conservation invariant
`hops[s, d] == route_links[s, d].sum()` (asserted at build time), so total
accumulated link load always equals `sum(weight * hops)` on any topology.

The builder output is cached per `NMPConfig` (`get_topology`); the config
carries only the declarative `topology` name, so jitted engine code (cfg is
a static argument) embeds the tensors as constants at trace time — routes
are computed once at build time, never per epoch.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque

import jax.numpy as jnp
import numpy as np

from repro.nmp.config import NMPConfig


@dataclasses.dataclass(frozen=True)
class Topology:
    """Host-side routing tensors for one cube interconnect (see module doc).

    All arrays are numpy; jitted consumers embed them as constants at trace
    time (the config they derive from is static)."""
    name: str
    n_cubes: int
    n_links: int
    mc_cubes: tuple[int, ...]
    hops: np.ndarray           # (C, C) int32
    route_links: np.ndarray    # (C, C, L) float32, 0/1
    nearest_mc: np.ndarray     # (C,) int32
    nbr: np.ndarray            # (C, D) int32 neighbor table (self-padded)
    nbr_valid: np.ndarray      # (C, D) bool
    far: np.ndarray            # (C,) int32 "far" remap target
    # Kernel-friendly layouts of the same tensors (see kernels/epoch_fused):
    # pair-indexed flattenings so the fused epoch kernel can express the
    # route gather + einsum as one-hot matmuls over a (C*C, ...) table.
    routes_flat: np.ndarray    # (C*C, L) float32 == route_links.reshape
    hops_flat: np.ndarray      # (C*C,) float32 == hops.reshape (exact ints)

    @property
    def max_degree(self) -> int:
        return int(self.nbr.shape[1])


# ---------------------------------------------------------------------------
# JAX-facing tensor API (what the engine calls)
# ---------------------------------------------------------------------------

def hop_count(topo: Topology, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Route length (link traversals) between cube ids — a pure gather."""
    return jnp.asarray(topo.hops)[a, b]


def link_loads(topo: Topology, src: jnp.ndarray, dst: jnp.ndarray,
               weight: jnp.ndarray) -> jnp.ndarray:
    """Accumulate flow `weight` (flits) over every link on each route.

    src, dst: (F,) cube ids; weight: (F,) flits.  Returns (n_links,) loads.
    One gather of the precomputed route-link incidence rows + one einsum —
    no per-epoch route construction, on any topology."""
    routes = jnp.asarray(topo.route_links)[src, dst]          # (F, L)
    return jnp.einsum("f,fl->l", weight.astype(jnp.float32), routes)


# ---------------------------------------------------------------------------
# Generic graph machinery (shared by the non-mesh builders)
# ---------------------------------------------------------------------------

def _routes_from_edges(n_cubes: int, edges: list[tuple[int, int]]
                       ) -> tuple[np.ndarray, np.ndarray]:
    """(hops, route_links) for minimal routing over an undirected edge list.

    Deterministic BFS from every source (neighbors visited in ascending cube
    order, first-discovered parent wins), so route choice is stable across
    builds.  `edges[l]` defines link id l."""
    L = len(edges)
    adj: list[list[tuple[int, int]]] = [[] for _ in range(n_cubes)]
    for l, (a, b) in enumerate(edges):
        adj[a].append((b, l))
        adj[b].append((a, l))
    for lst in adj:
        lst.sort()
    hops = np.full((n_cubes, n_cubes), -1, np.int32)
    routes = np.zeros((n_cubes, n_cubes, L), np.float32)
    for s in range(n_cubes):
        parent = np.full(n_cubes, -1, np.int64)
        plink = np.full(n_cubes, -1, np.int64)
        hops[s, s] = 0
        q = deque([s])
        while q:
            u = q.popleft()
            for v, l in adj[u]:
                if hops[s, v] < 0:
                    hops[s, v] = hops[s, u] + 1
                    parent[v], plink[v] = u, l
                    q.append(v)
        if (hops[s] < 0).any():
            missing = np.flatnonzero(hops[s] < 0)
            raise ValueError(f"disconnected topology: cube {s} cannot reach "
                             f"cubes {missing.tolist()}")
        for d in range(n_cubes):
            u = d
            while u != s:
                routes[s, d, plink[u]] = 1.0
                u = parent[u]
    return hops, routes


def _nbr_from_edges(n_cubes: int, edges: list[tuple[int, int]]
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Self-padded (C, D) neighbor table from an edge list (ascending order)."""
    neigh: list[list[int]] = [[] for _ in range(n_cubes)]
    for a, b in edges:
        neigh[a].append(b)
        neigh[b].append(a)
    D = max(len(n) for n in neigh)
    nbr = np.tile(np.arange(n_cubes, dtype=np.int32)[:, None], (1, D))
    valid = np.zeros((n_cubes, D), bool)
    for c, lst in enumerate(neigh):
        lst = sorted(lst)
        nbr[c, :len(lst)] = lst
        valid[c, :len(lst)] = True
    return nbr, valid


def _far_by_hops(hops: np.ndarray) -> np.ndarray:
    """Farthest cube per cube (ties -> lowest cube id)."""
    return np.argmax(hops, axis=1).astype(np.int32)


def _nearest_mc(hops: np.ndarray, mc_cubes: tuple[int, ...]) -> np.ndarray:
    """Cube -> nearest-MC index (ties broken by MC order)."""
    return np.argmin(hops[:, list(mc_cubes)], axis=1).astype(np.int32)


def _spread_mc_cubes(n_cubes: int, n_mcs: int) -> tuple[int, ...]:
    """Evenly spaced MC attachment points for topologies without corners
    (distinct whenever n_cubes >= n_mcs; `_finish` rejects the rest)."""
    return tuple(int(round(i * n_cubes / n_mcs)) % n_cubes
                 for i in range(n_mcs))


def _finish(name: str, cfg: NMPConfig, edges: list[tuple[int, int]],
            mc_cubes: tuple[int, ...], *,
            hops: np.ndarray | None = None,
            routes: np.ndarray | None = None,
            nbr: np.ndarray | None = None,
            nbr_valid: np.ndarray | None = None,
            far: np.ndarray | None = None) -> Topology:
    """Assemble + validate a Topology (conservation asserted at build time)."""
    C = cfg.n_cubes
    if hops is None or routes is None:
        hops, routes = _routes_from_edges(C, edges)
    if nbr is None or nbr_valid is None:
        nbr, nbr_valid = _nbr_from_edges(C, edges)
    if far is None:
        far = _far_by_hops(hops)
    np.testing.assert_array_equal(routes.sum(axis=-1), hops,
                                  err_msg=f"{name}: route length != hops")
    assert (hops == hops.T).all(), f"{name}: asymmetric hop matrix"
    if len(set(mc_cubes)) != len(mc_cubes):
        # Silently piling several controllers onto one cube would leave the
        # cost model injecting at n_mcs rates while routing to fewer live
        # MCs — refuse the degenerate geometry instead.
        raise ValueError(f"{name}: duplicate MC attachment cubes {mc_cubes} "
                         f"(geometry too small for {len(mc_cubes)} MCs)")
    if len(mc_cubes) != cfg.n_mcs:
        # The engine sizes its MC-queue state to cfg.n_mcs; an attachment
        # list of any other length would silently drop scattered traffic
        # (out-of-bounds scatter) or leave dead queue slots.  mesh2d/torus2d
        # pin one MC per CMP corner, so they only support n_mcs == 4.
        raise ValueError(f"{name}: {len(mc_cubes)} MC attachment cubes for "
                         f"n_mcs={cfg.n_mcs}")
    return Topology(name=name, n_cubes=C, n_links=len(edges),
                    mc_cubes=tuple(int(m) for m in mc_cubes),
                    hops=hops.astype(np.int32), route_links=routes,
                    nearest_mc=_nearest_mc(hops, mc_cubes),
                    nbr=nbr, nbr_valid=nbr_valid, far=far.astype(np.int32),
                    routes_flat=np.ascontiguousarray(
                        routes.reshape(C * C, len(edges))),
                    hops_flat=hops.reshape(C * C).astype(np.float32))


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------

def mesh2d(cfg: NMPConfig) -> Topology:
    """The paper's 2D mesh with static XY routing — bit-identical to the
    historical `nmp.network` model.

    Link indexing (undirected, contention aggregates both directions):
      horizontal link (y, x <-> x+1):  id = y * (X-1) + x      for x in [0, X-1)
      vertical   link (x, y <-> y+1):  id = H + x * (Y-1) + y  for y in [0, Y-1)
    XY routes traverse X at the source row, then Y at the destination column.
    The neighbor table keeps the historical candidate slot order
    [x-1, x+1, y-1, y+1] (invalid slots = self) and `far` is the historical
    mirror through the array center — NOT the hop-farthest cube."""
    X, Y = cfg.mesh_x, cfg.mesh_y
    C = X * Y
    H = Y * (X - 1)
    L = H + X * (Y - 1)
    edges = ([(y * X + x, y * X + x + 1) for y in range(Y)
              for x in range(X - 1)]
             + [(y * X + x, (y + 1) * X + x) for x in range(X)
                for y in range(Y - 1)])
    assert len(edges) == L

    cx, cy = np.arange(C) % X, np.arange(C) // X
    hops = (np.abs(cx[:, None] - cx[None, :])
            + np.abs(cy[:, None] - cy[None, :])).astype(np.int32)
    routes = np.zeros((C, C, L), np.float32)
    for s in range(C):
        for d in range(C):
            sx, sy, dx, dy = cx[s], cy[s], cx[d], cy[d]
            for x in range(min(sx, dx), max(sx, dx)):     # X at the source row
                routes[s, d, sy * (X - 1) + x] = 1.0
            for y in range(min(sy, dy), max(sy, dy)):     # Y at the dest column
                routes[s, d, H + dx * (Y - 1) + y] = 1.0

    # historical candidate slot order: [x-1, x+1, y-1, y+1]
    cand_x = np.stack([cx - 1, cx + 1, cx, cx], axis=1)
    cand_y = np.stack([cy, cy, cy - 1, cy + 1], axis=1)
    valid = ((cand_x >= 0) & (cand_x < X) & (cand_y >= 0) & (cand_y < Y))
    nbr = np.where(valid, cand_y * X + cand_x, np.arange(C)[:, None])
    far = ((Y - 1 - cy) * X + (X - 1 - cx)).astype(np.int32)
    return _finish("mesh2d", cfg, edges, cfg.mc_cubes, hops=hops,
                   routes=routes, nbr=nbr.astype(np.int32),
                   nbr_valid=valid, far=far)


def torus2d(cfg: NMPConfig) -> Topology:
    """2D torus: the mesh plus X/Y wraparound links (every row and column is
    a ring).  Minimal routes via deterministic BFS; the corner MCs of the
    mesh keep their attachment points (the torus has no corners, but the
    package pins the controllers)."""
    X, Y = cfg.mesh_x, cfg.mesh_y
    edges = [(y * X + x, y * X + (x + 1) % X) for y in range(Y)
             for x in range(X if X > 2 else X - 1)]
    edges += [(y * X + x, ((y + 1) % Y) * X + x) for x in range(X)
              for y in range(Y if Y > 2 else Y - 1)]
    return _finish("torus2d", cfg, edges, cfg.mc_cubes)


def ring(cfg: NMPConfig) -> Topology:
    """Single bidirectional ring over all C cubes (cube i <-> i+1 mod C) —
    the cheapest interconnect, the worst bisection.  MCs attach at evenly
    spaced cubes."""
    C = cfg.n_cubes
    edges = [(i, (i + 1) % C) for i in range(C if C > 2 else C - 1)]
    return _finish("ring", cfg, edges, _spread_mc_cubes(C, cfg.n_mcs))


def dragonfly(cfg: NMPConfig) -> Topology:
    """Dragonfly: `mesh_y` groups of `mesh_x` cubes, all-to-all links inside
    each group, one global link per group pair (attached round-robin over
    each group's cubes).  Minimal group-direct routes (<= 3 hops) via BFS.
    MCs attach at evenly spaced cubes (the first cube of each group on the
    default square geometry)."""
    a, g = cfg.mesh_x, cfg.mesh_y
    C = a * g
    edges = [(gi * a + i, gi * a + j) for gi in range(g)
             for i in range(a) for j in range(i + 1, a)]
    for g1 in range(g):
        for g2 in range(g1 + 1, g):
            edges.append((g1 * a + g2 % a, g2 * a + g1 % a))
    return _finish("dragonfly", cfg, edges, _spread_mc_cubes(C, cfg.n_mcs))


TOPOLOGIES: dict[str, callable] = {
    "mesh2d": mesh2d,
    "torus2d": torus2d,
    "ring": ring,
    "dragonfly": dragonfly,
}


def validate_topology(name: str) -> str:
    """Return `name` if it names a registered builder, else raise — the one
    validation every layer (config resolution, scenario builders, plan)
    shares."""
    if name not in TOPOLOGIES:
        raise ValueError(f"unknown topology {name!r}; expected one of "
                         f"{sorted(TOPOLOGIES)}")
    return name


def build_topology(cfg: NMPConfig) -> Topology:
    """Build the routing tensors `cfg.topology` declares (uncached)."""
    return TOPOLOGIES[validate_topology(cfg.topology)](cfg)


@functools.lru_cache(maxsize=None)
def _build_cached(topology: str, mesh_x: int, mesh_y: int,
                  n_mcs: int) -> Topology:
    return build_topology(NMPConfig(topology=topology, mesh_x=mesh_x,
                                    mesh_y=mesh_y, n_mcs=n_mcs))


def get_topology(cfg: NMPConfig) -> Topology:
    """Cached routing tensors for a config — the one entry point jitted
    consumers use (cfg is a static argument, so the tensors are trace-time
    constants and every route is computed exactly once per process).  The
    cache keys on the fields the builders actually read (topology name +
    geometry), so configs differing only in timing/cache knobs — e.g. a
    sensitivity sweep — share one tensor set."""
    return _build_cached(cfg.topology, cfg.mesh_x, cfg.mesh_y, cfg.n_mcs)
