"""Continual-learning agent lifecycle layer: persistent policies across
scenarios, program switches and processes.

The paper's core claim is *continual* learning — AIMM "continuously evaluates
and learns the impact of mapping decisions ... for any application", surviving
program switches and co-runner churn.  The engine (nmp.engine) and the sweep
pipeline (nmp.plan / nmp.partition / nmp.sweep) simulate and train; this
module owns what happens to the DQN *between* compiled programs:

  PolicyStore   : a tag -> AgentState registry of agent lineages.  Lanes
                  declare a lineage via `Scenario.lineage`; `sweep.run_grid`
                  warm-starts declared lanes from the store (cold-starts a
                  fresh tag) and writes every tag's final agent back.  Agents
                  are held as host-side numpy snapshots (`agent.export_agent`),
                  so a store is independent of devices, meshes and jit.
  checkpointing : `PolicyStore.save` / `PolicyStore.restore` round-trip the
                  whole store through `train.checkpoint.CheckpointManager`
                  bit-exactly (replay buffer dtypes, Adam moments and the
                  PRNG key survive), so a long-running mapper can be stopped
                  mid-stream and resumed in a fresh process — on a different
                  device mesh — and reproduce the remaining stream exactly.
  run_stream    : execute an ordered program-phase stream (see
                  `scenarios.continual_stream`) as chained `run_grid` calls
                  threading one PolicyStore, i.e. one DQN living through app
                  switches and co-runner arrival/departure.

Scenario-boundary semantics (`PolicyStore.checkout`): the DNN weights, target
network, Adam moments, replay buffer, RNG stream and `global_step` carry
across the boundary; only the per-scenario interaction counter resets
(`agent.hand_off`).  The ε-greedy schedule keys on `global_step`, so
exploration keeps decaying over the agent's lifetime instead of restarting
with every program switch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import numpy as np

from repro.core import agent as agent_mod
from repro.core.agent import AgentConfig, AgentState
from repro.nmp.config import NMPConfig
from repro.nmp.scenarios import Scenario
from repro.train.checkpoint import (CheckpointCorruptError, CheckpointManager,
                                    decode_leaf)


def check_tag(tag: str) -> str:
    """Validate a lineage tag (also called by `plan_grid`, so a bad tag fails
    at plan time instead of after the whole grid has simulated)."""
    if not isinstance(tag, str) or not tag or "/" in tag:
        raise ValueError(
            f"lineage tag {tag!r}: expected a non-empty string without '/' "
            "(tags become checkpoint leaf-path components)")
    return tag


class PolicyStore:
    """Registry of persistent agent lineages, keyed by tag.

    Agents enter via `put` (stored as host numpy snapshots) and leave via
    `checkout` (device arrays, scenario-boundary handoff applied).  The store
    itself never trains — `sweep.run_grid` / `run_stream` thread it through
    compiled programs.  Per-tag `meta` records lineage provenance (last
    scenario, lifetime counters, phases served, a `version` bumped on every
    `put`).

    `capacity` bounds the number of resident lineages: `put` and `checkout`
    refresh a tag's recency, and a `put` that overflows the bound evicts the
    least-recently-used *other* tags (counted in `evictions`; per-tag
    eviction counts live on in `meta`, so a returning tag's `version`
    continues across evictions).  An evicted lineage simply cold-restarts on
    its next warm-start lookup — the serving layer (nmp.serving) relies on
    this to serve an unbounded tenant population from a finite store.  The
    default (`capacity=None`) is unbounded, the historical behavior."""

    def __init__(self, agents: dict[str, AgentState] | None = None,
                 meta: dict[str, dict] | None = None,
                 capacity: int | None = None):
        if capacity is not None and capacity < 1:
            raise ValueError(f"PolicyStore capacity must be >= 1 or None "
                             f"(got {capacity})")
        self.capacity = capacity
        self.evictions = 0               # lifetime eviction count
        self.rollbacks = 0               # lifetime rollback count
        self.restored_step = None        # checkpoint step this store came
                                         # from (set by `restore`), used by
                                         # run_stream to realign resumed
                                         # checkpoint histories
        self.restore_fallbacks = 0       # corrupt steps skipped by `restore`
        self.corrupt_tags: list[str] = []  # lineages dropped (cold-start) by
                                           # `restore` on per-tag corruption
        self._agents: dict[str, AgentState] = dict(agents or {})
        self._prev: dict[str, AgentState] = {}   # last-good snapshots
                                                 # (rollback depth 1)
        self.meta: dict[str, dict] = {t: dict(m)
                                      for t, m in (meta or {}).items()}
        self._evict_to_capacity()

    # -- registry -------------------------------------------------------
    @property
    def tags(self) -> list[str]:
        return sorted(self._agents)

    def __contains__(self, tag: str) -> bool:
        return tag in self._agents

    def __len__(self) -> int:
        return len(self._agents)

    def get(self, tag: str) -> AgentState:
        """The stored host-side snapshot (no handoff applied)."""
        return self._agents[tag]

    def put(self, tag: str, agent: AgentState, **meta: Any) -> None:
        """Store `agent` (detached to host numpy) as the lineage's current
        state, bump its `version` and update its provenance record.  With a
        bounded store this may evict least-recently-used other tags."""
        check_tag(tag)
        snap = agent_mod.export_agent(agent)
        prev = self._agents.pop(tag, None)   # re-insert = most recent
        if prev is not None:
            self._prev[tag] = prev           # last-good rollback snapshot
        self._agents[tag] = snap
        rec = self.meta.setdefault(tag, {"phases": 0})
        rec["phases"] = rec.get("phases", 0) + 1
        rec["version"] = rec.get("version", 0) + 1
        rec["global_step"] = int(snap.global_step)
        rec["train_steps"] = int(snap.train_steps)
        rec.update(meta)
        self._evict_to_capacity()

    def checkout(self, tag: str) -> AgentState:
        """Device-ready warm start for a new scenario: the stored lineage
        with the scenario-boundary handoff applied (per-scenario counters
        reset; weights, replay, RNG and global_step carried).  Refreshes the
        tag's LRU recency."""
        self._agents[tag] = self._agents.pop(tag)
        return agent_mod.hand_off(agent_mod.import_agent(self._agents[tag]))

    def checkout_host(self, tag: str) -> AgentState:
        """`checkout` without the device import: the stored numpy snapshot
        with the scenario-boundary handoff applied host-side (LRU recency
        refreshed the same way).  The staging-buffer warm-batch path
        (`sweep.AgentStaging`) fills preallocated host buffers from these
        and pays one device transfer per *leaf* instead of one per cell —
        the leaf values (incl. the zeroed `step`) are bit-identical to
        `checkout`'s."""
        self._agents[tag] = self._agents.pop(tag)
        return self._agents[tag]._replace(step=np.zeros((), np.int32))

    def version(self, tag: str) -> int:
        """Lifetime `put` count of a lineage (survives eviction)."""
        return int(self.meta[tag].get("version", 0))

    def rollback(self, tag: str) -> bool:
        """Revert a lineage to its last-good version (the snapshot the most
        recent `put` replaced) — the divergence-recovery path: a poisoned or
        diverged current snapshot is discarded and the lineage resumes from
        the version before it.  With no prior version the current snapshot
        is simply dropped, so the lineage cold-restarts on its next lookup.
        Returns True when a prior snapshot was restored."""
        self.rollbacks += 1
        rec = self.meta.setdefault(tag, {})
        rec["rollbacks"] = rec.get("rollbacks", 0) + 1
        self._agents.pop(tag, None)          # discard the bad current
        prev = self._prev.pop(tag, None)
        if prev is None:
            return False
        self._agents[tag] = prev             # restored = most recent
        return True

    # -- bounded capacity ----------------------------------------------
    def evict(self, tag: str) -> None:
        """Drop a lineage's resident agent.  Its `meta` record stays (with
        an `evicted` count), so versioning continues if the tag returns; a
        later warm-start lookup simply misses and cold-restarts."""
        del self._agents[tag]
        self._prev.pop(tag, None)
        self.evictions += 1
        rec = self.meta.setdefault(tag, {})
        rec["evicted"] = rec.get("evicted", 0) + 1

    def _evict_to_capacity(self) -> None:
        if self.capacity is None:
            return
        while len(self._agents) > self.capacity:
            self.evict(next(iter(self._agents)))     # insertion order = LRU

    def global_step(self, tag: str) -> int:
        """Lifetime env interactions of a lineage."""
        return int(self._agents[tag].global_step)

    # -- persistence ----------------------------------------------------
    def save(self, directory: str, step: int | None = None,
             keep: int = 0) -> int:
        """Checkpoint every lineage (synchronously) via CheckpointManager.

        `step` defaults to latest+1 so repeated saves of a long-running
        stream form a history.  Every step is kept by default (`keep=0`) —
        a stream checkpoints once per phase and any phase must stay a valid
        resume point; pass `keep > 0` to bound the history instead."""
        mgr = CheckpointManager(directory, keep=keep, async_write=False)
        if step is None:
            latest = mgr.latest_step()
            step = 0 if latest is None else latest + 1
        mgr.save(step, dict(self._agents),
                 extras={"tags": self.tags, "meta": self.meta,
                         "capacity": self.capacity,
                         "evictions": self.evictions,
                         "rollbacks": self.rollbacks})
        return step

    @classmethod
    def restore(cls, directory: str, agent_cfg: AgentConfig,
                step: int | None = None) -> "PolicyStore":
        """Rebuild a store in a fresh process: read the checkpoint's tag list
        from its metadata, build RNG-free `agent_template` skeletons, and map
        the saved leaves back on bit-exactly.  `agent_cfg` must describe the
        same agent architecture the store was saved with.

        Corruption tolerance: with `step=None`, unreadable steps (torn
        commit, garbage meta, unopenable shard) are skipped newest-first —
        counted in `restore_fallbacks` — until an intact one restores.
        Within a readable step, a lineage whose own leaves fail their
        recorded checksums is dropped from the store (listed in
        `corrupt_tags`; its `meta` record survives with a `corrupt_restore`
        mark) while every other lineage restores bit-exactly, so one
        corrupted tag cold-starts instead of poisoning the whole store.
        An explicitly requested bad `step` raises `CheckpointCorruptError`.

        The restored store remembers the checkpoint step it came from
        (`restored_step`), which `run_stream` uses to keep the step <-> phase
        alignment when a stream resumes from a non-latest step."""
        mgr = CheckpointManager(directory)
        explicit = step is not None
        steps = [step] if explicit else list(reversed(mgr.all_steps()))
        if not steps:
            raise FileNotFoundError(
                f"no checkpoints in {directory!r}: the directory holds no "
                "committed step_<k> entries")
        skipped = 0
        last_err: Exception | None = None
        for s in steps:
            try:
                store = cls._restore_step(mgr, s, agent_cfg)
                store.restore_fallbacks = skipped
                return store
            except CheckpointCorruptError as e:
                if explicit:
                    raise
                skipped += 1
                last_err = e
        raise CheckpointCorruptError(
            f"no intact checkpoint step in {directory!r} "
            f"({skipped} corrupt step(s) skipped): {last_err}")

    @classmethod
    def _restore_step(cls, mgr: CheckpointManager, step: int,
                      agent_cfg: AgentConfig) -> "PolicyStore":
        import jax
        arrays, meta, bad = mgr.load_arrays(step)
        extras = meta["extras"]
        agents: dict[str, AgentState] = {}
        corrupt: list[str] = []
        for tag in extras["tags"]:
            tmpl = agent_mod.agent_template(agent_cfg)
            flat, treedef = jax.tree_util.tree_flatten_with_path({tag: tmpl})
            leaves, ok = [], True
            for path, _leaf in flat:
                key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                               for p in path)
                if key in bad or key not in arrays:
                    ok = False
                    break
                leaves.append(np.asarray(decode_leaf(
                    arrays[key], meta["leaves"][key]["dtype"])))
            if ok:
                tree = jax.tree_util.tree_unflatten(treedef, leaves)
                agents[tag] = agent_mod.export_agent(tree[tag])
            else:
                corrupt.append(tag)
        if not agents and extras["tags"]:
            raise CheckpointCorruptError(
                f"checkpoint step {step}: every lineage failed verification")
        store = cls(agents=agents, meta=extras.get("meta", {}),
                    capacity=extras.get("capacity"))
        for tag in corrupt:
            rec = store.meta.setdefault(tag, {})
            rec["corrupt_restore"] = rec.get("corrupt_restore", 0) + 1
        store.corrupt_tags = corrupt
        store.evictions = int(extras.get("evictions", 0))
        store.rollbacks = int(extras.get("rollbacks", 0))
        store.restored_step = int(meta["step"])
        return store


@dataclasses.dataclass
class StreamResult:
    """One executed program-phase stream: per-phase SweepResults plus the
    PolicyStore holding every lineage's final agent."""
    phases: list[Any]                # list[sweep.SweepResult], in phase order
    store: PolicyStore

    def phase_summary(self, phase: int, lane: int,
                      episode: int | None = None) -> dict:
        return self.phases[phase].episode_summary(lane, episode)


def run_stream(stream: Sequence[Sequence[Scenario]],
               cfg: NMPConfig = NMPConfig(),
               agent_cfg: AgentConfig | None = None,
               store: PolicyStore | None = None,
               checkpoint_dir: str | None = None,
               checkpoint_base_step: int | None = None,
               faults=None) -> StreamResult:
    """Execute an ordered program-phase stream as chained `run_grid` calls.

    Each phase is one grid (see `scenarios.continual_stream`); the store is
    threaded through, so lanes sharing a lineage tag across phases are one
    DQN living through every app switch and co-runner change.

    With `checkpoint_dir` the store is checkpointed after every phase at
    step `base + phase_index`, where the base is (first match wins):

      * `checkpoint_base_step`, when given explicitly;
      * `store.restored_step + 1`, when the store came from
        `PolicyStore.restore` — so a stream resumed from step `k` writes its
        phases at `k+1, k+2, ...`, *re-aligning* the directory's step <->
        phase-index mapping even when `k` is not the latest step (resuming
        from an older step overwrites the now-stale later steps instead of
        appending misaligned ones after them);
      * the directory's `latest+1` continuation otherwise (a fresh directory
        starts at step == phase index).

    That is the stop/resume protocol for long-running streams:
    `PolicyStore.restore(dir, agent_cfg, step=k)` +
    `run_stream(stream[k+1:], store=..., checkpoint_dir=dir)` reproduces the
    remaining phases bit-exactly, with every step in the directory mapping
    to the phase of the same index.

    `faults` is an optional `nmp.faults.FaultPlan` — the deterministic
    fault-injection harness.  Its `on_phase` hook fires before each phase
    (poisoning stored lineages, stalling, or failing the phase) and its
    `on_checkpoint` hook fires after each save (corrupting checkpoint bytes
    on disk), so recovery paths can be exercised end to end.  With
    `faults=None` (the default) neither hook site costs anything."""
    from repro.nmp.sweep import run_grid
    store = store if store is not None else PolicyStore()
    base = checkpoint_base_step
    if base is None and store.restored_step is not None:
        base = store.restored_step + 1
    results = []
    for pi, phase in enumerate(stream):
        if faults is not None:
            faults.on_phase(pi, store)
        results.append(run_grid(phase, cfg, agent_cfg, store=store))
        if checkpoint_dir is not None:
            store.save(checkpoint_dir,
                       step=None if base is None else base + pi)
            if faults is not None:
                faults.on_checkpoint(checkpoint_dir)
    return StreamResult(phases=results, store=store)
