"""Continual-learning agent lifecycle layer: persistent policies across
scenarios, program switches and processes.

The paper's core claim is *continual* learning — AIMM "continuously evaluates
and learns the impact of mapping decisions ... for any application", surviving
program switches and co-runner churn.  The engine (nmp.engine) and the sweep
pipeline (nmp.plan / nmp.partition / nmp.sweep) simulate and train; this
module owns what happens to the DQN *between* compiled programs:

  PolicyStore   : a tag -> AgentState registry of agent lineages.  Lanes
                  declare a lineage via `Scenario.lineage`; `sweep.run_grid`
                  warm-starts declared lanes from the store (cold-starts a
                  fresh tag) and writes every tag's final agent back.  Agents
                  are held as host-side numpy snapshots (`agent.export_agent`),
                  so a store is independent of devices, meshes and jit.
  checkpointing : `PolicyStore.save` / `PolicyStore.restore` round-trip the
                  whole store through `train.checkpoint.CheckpointManager`
                  bit-exactly (replay buffer dtypes, Adam moments and the
                  PRNG key survive), so a long-running mapper can be stopped
                  mid-stream and resumed in a fresh process — on a different
                  device mesh — and reproduce the remaining stream exactly.
  run_stream    : execute an ordered program-phase stream (see
                  `scenarios.continual_stream`) as chained `run_grid` calls
                  threading one PolicyStore, i.e. one DQN living through app
                  switches and co-runner arrival/departure.

Scenario-boundary semantics (`PolicyStore.checkout`): the DNN weights, target
network, Adam moments, replay buffer, RNG stream and `global_step` carry
across the boundary; only the per-scenario interaction counter resets
(`agent.hand_off`).  The ε-greedy schedule keys on `global_step`, so
exploration keeps decaying over the agent's lifetime instead of restarting
with every program switch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

from repro.core import agent as agent_mod
from repro.core.agent import AgentConfig, AgentState
from repro.nmp.config import NMPConfig
from repro.nmp.scenarios import Scenario
from repro.train.checkpoint import CheckpointManager


def check_tag(tag: str) -> str:
    """Validate a lineage tag (also called by `plan_grid`, so a bad tag fails
    at plan time instead of after the whole grid has simulated)."""
    if not isinstance(tag, str) or not tag or "/" in tag:
        raise ValueError(
            f"lineage tag {tag!r}: expected a non-empty string without '/' "
            "(tags become checkpoint leaf-path components)")
    return tag


class PolicyStore:
    """Registry of persistent agent lineages, keyed by tag.

    Agents enter via `put` (stored as host numpy snapshots) and leave via
    `checkout` (device arrays, scenario-boundary handoff applied).  The store
    itself never trains — `sweep.run_grid` / `run_stream` thread it through
    compiled programs.  Per-tag `meta` records lineage provenance (last
    scenario, lifetime counters, phases served)."""

    def __init__(self, agents: dict[str, AgentState] | None = None,
                 meta: dict[str, dict] | None = None):
        self._agents: dict[str, AgentState] = dict(agents or {})
        self.meta: dict[str, dict] = {t: dict(m)
                                      for t, m in (meta or {}).items()}

    # -- registry -------------------------------------------------------
    @property
    def tags(self) -> list[str]:
        return sorted(self._agents)

    def __contains__(self, tag: str) -> bool:
        return tag in self._agents

    def __len__(self) -> int:
        return len(self._agents)

    def get(self, tag: str) -> AgentState:
        """The stored host-side snapshot (no handoff applied)."""
        return self._agents[tag]

    def put(self, tag: str, agent: AgentState, **meta: Any) -> None:
        """Store `agent` (detached to host numpy) as the lineage's current
        state and update its provenance record."""
        check_tag(tag)
        snap = agent_mod.export_agent(agent)
        self._agents[tag] = snap
        rec = self.meta.setdefault(tag, {"phases": 0})
        rec["phases"] = rec.get("phases", 0) + 1
        rec["global_step"] = int(snap.global_step)
        rec["train_steps"] = int(snap.train_steps)
        rec.update(meta)

    def checkout(self, tag: str) -> AgentState:
        """Device-ready warm start for a new scenario: the stored lineage
        with the scenario-boundary handoff applied (per-scenario counters
        reset; weights, replay, RNG and global_step carried)."""
        return agent_mod.hand_off(agent_mod.import_agent(self._agents[tag]))

    def global_step(self, tag: str) -> int:
        """Lifetime env interactions of a lineage."""
        return int(self._agents[tag].global_step)

    # -- persistence ----------------------------------------------------
    def save(self, directory: str, step: int | None = None,
             keep: int = 0) -> int:
        """Checkpoint every lineage (synchronously) via CheckpointManager.

        `step` defaults to latest+1 so repeated saves of a long-running
        stream form a history.  Every step is kept by default (`keep=0`) —
        a stream checkpoints once per phase and any phase must stay a valid
        resume point; pass `keep > 0` to bound the history instead."""
        mgr = CheckpointManager(directory, keep=keep, async_write=False)
        if step is None:
            latest = mgr.latest_step()
            step = 0 if latest is None else latest + 1
        mgr.save(step, dict(self._agents),
                 extras={"tags": self.tags, "meta": self.meta})
        return step

    @classmethod
    def restore(cls, directory: str, agent_cfg: AgentConfig,
                step: int | None = None) -> "PolicyStore":
        """Rebuild a store in a fresh process: read the checkpoint's tag list
        from its metadata, build RNG-free `agent_template` skeletons, and map
        the saved leaves back on bit-exactly.  `agent_cfg` must describe the
        same agent architecture the store was saved with."""
        mgr = CheckpointManager(directory)
        meta = mgr.read_meta(step)
        template = {t: agent_mod.agent_template(agent_cfg)
                    for t in meta["extras"]["tags"]}
        tree, extras = mgr.restore(template, step)
        agents = {t: agent_mod.export_agent(a) for t, a in tree.items()}
        return cls(agents=agents, meta=extras.get("meta", {}))


@dataclasses.dataclass
class StreamResult:
    """One executed program-phase stream: per-phase SweepResults plus the
    PolicyStore holding every lineage's final agent."""
    phases: list[Any]                # list[sweep.SweepResult], in phase order
    store: PolicyStore

    def phase_summary(self, phase: int, lane: int,
                      episode: int | None = None) -> dict:
        return self.phases[phase].episode_summary(lane, episode)


def run_stream(stream: Sequence[Sequence[Scenario]],
               cfg: NMPConfig = NMPConfig(),
               agent_cfg: AgentConfig | None = None,
               store: PolicyStore | None = None,
               checkpoint_dir: str | None = None) -> StreamResult:
    """Execute an ordered program-phase stream as chained `run_grid` calls.

    Each phase is one grid (see `scenarios.continual_stream`); the store is
    threaded through, so lanes sharing a lineage tag across phases are one
    DQN living through every app switch and co-runner change.  With
    `checkpoint_dir` the store is checkpointed after every phase, the steps
    continuing the directory's existing history (so on a fresh directory
    step == phase index, and a *resumed* stream appends instead of
    clobbering earlier phases' resume points).  That is the stop/resume
    protocol for long-running streams: `PolicyStore.restore(dir, agent_cfg,
    step=k)` + `run_stream(stream[k+1:], store=...)` reproduces the
    remaining phases bit-exactly."""
    from repro.nmp.sweep import run_grid
    store = store if store is not None else PolicyStore()
    results = []
    for phase in stream:
        results.append(run_grid(phase, cfg, agent_cfg, store=store))
        if checkpoint_dir is not None:
            store.save(checkpoint_dir)
    return StreamResult(phases=results, store=store)
