"""Plan layer of the sweep pipeline: declarative normalization of a grid.

`plan_grid` turns a flat list of `scenarios.Scenario` cells into a
`GridPlan` — the complete, backend-agnostic description of how the grid will
execute:

  * **envelope**: the shared spatial envelope (op count, page count, epoch
    count, OPC-ring length) every lane is padded to, so per-lane metrics and
    the stacked final env have one shape;
  * **seed folding**: scenarios identical up to their `seed` collapse into
    one `LanePlan` with a seed axis — the execute layer vmaps that axis
    inside the lane, so S seed replicas share a single copy of the trace
    arrays and every lane gets mean±std variance bands for free.  Lanes
    whose results provably cannot depend on the seed (deterministic
    mappers, see `seed_invariant`) collapse to a width-1 seed axis: one
    simulated cell serves every replica;
  * **lane grouping**: lanes are grouped by DQN-liveness (`needs_agent`),
    agent-lineage mode (`lane_lineage`: warm-capable lanes whose agent
    batch is threaded in/out of the program vs plain cold-start lanes) and
    cube topology (`scenario_topology`: interconnects have different link
    spaces and routing tensors, so a mixed-topology grid compiles one
    program per topology group), with per-group `engine.BodyFlags`
    recording which machinery (AIMM actions, TOM scoring, PEI thresholding)
    any lane of the group uses, so unused features compile out.  A
    single-topology mixed grid compiles at most three programs — one per
    agent-mode group — exactly the historical layout.

`build_group_batch` materializes one group's numpy input batch (trace arrays
per lane, episode seed schedules per (lane, seed)); the partition layer
(`nmp.partition`) then pads + shards it over a device mesh and the execute
layer (`nmp.sweep`) runs it.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Sequence

import numpy as np

from repro.kernels.epoch_fused import ops as epoch_ops
from repro.nmp import baselines
from repro.nmp.config import NMPConfig
from repro.nmp.engine import (BodyFlags, make_ctx, pad_trace_ops, pei_top_k,
                              phase_ring_len, serial_epochs)
from repro.nmp.paging import default_alloc
from repro.nmp.scenarios import Scenario


def needs_agent(sc: Scenario) -> bool:
    """A lane carries a live DQN iff it is a learned-policy AIMM cell."""
    return sc.mapper == "aimm" and sc.forced_action < 0


def scenario_topology(sc: Scenario, cfg: NMPConfig) -> str:
    """Effective cube interconnect of a lane: the scenario's own
    `topology` tag, falling back to the sweep config's."""
    return sc.topology if sc.topology is not None else cfg.topology


def lane_lineage(sc: Scenario) -> str | None:
    """The PolicyStore tag of a lane's agent lineage, or None for a plain
    cold-start lane.  Only learned-policy AIMM lanes carry an agent, so a
    lineage tag on any other cell is inert and normalized away here."""
    return sc.lineage if needs_agent(sc) else None


_ENV_SEED_SHARE = "REPRO_SEED_SHARE"


def seed_share_enabled() -> bool:
    """Whether seed-invariant work sharing (engine.SharedEpoch hoisted out of
    the seed vmap) is enabled.  On by default; REPRO_SEED_SHARE=off forces
    the historical recompute-per-replica path (the A/B baseline in
    benchmarks/bench_fleet.py).  Bit-identical either way."""
    raw = os.environ.get(_ENV_SEED_SHARE, "on").strip().lower()
    if raw in ("", "on", "1"):
        return True
    if raw in ("off", "0"):
        return False
    raise ValueError(f"{_ENV_SEED_SHARE}={raw!r}: expected 'on' or 'off'")


def seed_invariant(sc: Scenario) -> bool:
    """True when the scenario's results cannot depend on its seed.

    The seed enters the engine only through the env RNG (and the DQN init),
    and the env RNG is consumed exclusively by AIMM lanes (random-neighbor
    action targets, ε-greedy exploration).  Deterministic mappers therefore
    produce bit-identical metrics for every seed, and the plan collapses
    their folded seed axis to width 1 — one simulated cell serves all seed
    replicas instead of re-simulating identical work per seed."""
    return sc.mapper != "aimm"




@dataclasses.dataclass(frozen=True)
class LanePlan:
    """One folded lane: a representative scenario plus its seed axis.

    `seeds` holds the simulated seed-axis values, padded to the group's
    common width S by repeating the first seed (padding slots are simulated
    and dropped).  `indices[k]` is the original grid index of the lane's
    k-th folded scenario and `slots[k]` the seed-axis slot its results come
    from — for a seed-invariant lane every scenario reads slot 0 of a
    width-1 axis."""
    scenario: Scenario
    seeds: tuple[int, ...]
    indices: tuple[int, ...]
    slots: tuple[int, ...]

    @property
    def n_seeds(self) -> int:
        return len(self.seeds)


@dataclasses.dataclass(frozen=True)
class GroupPlan:
    """One compiled program: lanes sharing an agent mode, a lineage mode, a
    seed-axis width and an episode count.

    `lineage=True` marks the warm-capable program: its initial agent batch is
    an *input* (warm-started from a PolicyStore or cold-started on a fresh
    lineage) and its final agent batch an output.  Lineage-free lanes compile
    the exact historical program — agents born and dropped inside the jit —
    so grids without lineages stay bit-identical to pre-lifecycle builds."""
    lanes: tuple[LanePlan, ...]
    has_agent: bool
    flags: BodyFlags
    n_episodes: int              # per-group padded episode count
    n_seeds: int                 # common (padded) seed-axis width S
    lineage: bool = False        # agent batch threaded in/out of the program
    topology: str = "mesh2d"     # cube interconnect every lane of the group
                                 # simulates (the execute layer runs the
                                 # group under cfg resolved to it)

    @property
    def n_lanes(self) -> int:
        return len(self.lanes)


@dataclasses.dataclass(frozen=True)
class GridPlan:
    """Declarative execution plan for a scenario grid (see module docstring)."""
    scenarios: tuple[Scenario, ...]
    groups: tuple[GroupPlan, ...]
    n_ops_max: int
    n_pages_max: int
    n_epochs: int
    ring_len: int
    n_episodes: int              # global padded episode count (presentation)
    agent_lineage: tuple[str | None, ...] = ()
                                 # per-scenario PolicyStore tag (grid order):
                                 # None = cold-start, shared tag = lanes in
                                 # one warm-start / shared-agent group
    topologies: tuple[str, ...] = ()
                                 # per-scenario effective interconnect (grid
                                 # order, cfg fallback resolved)

    @property
    def n_lanes(self) -> int:
        return sum(g.n_lanes for g in self.groups)

    def lineage_tags(self) -> tuple[str, ...]:
        """Distinct lineage tags the grid declares, in first-seen order."""
        return tuple(dict.fromkeys(t for t in self.agent_lineage
                                   if t is not None))

    def seed_group(self, index: int) -> tuple[int, ...]:
        """Original grid indices of every seed replica folded into the same
        lane as scenario `index` (always contains `index`)."""
        for g in self.groups:
            for lane in g.lanes:
                if index in lane.indices:
                    return lane.indices
        raise IndexError(index)


def lane_cost(lane: LanePlan) -> int:
    """Padded device cost proxy of one folded lane: real op count × episode
    schedule length × simulated seed width.  Drives the throughput-tuned
    shard packing (`_fold_lanes` ordering, `packed_group_order`)."""
    sc = lane.scenario
    return sc.trace.n_ops * sc.total_episodes * lane.n_seeds


def _fold_lanes(scenarios: Sequence[Scenario],
                idxs: Sequence[int]) -> list[LanePlan]:
    """Fold one group's scenarios by `fold_key`, then order lanes by
    descending padded cost (`lane_cost`), stably — first-seen order breaks
    ties.  Cost-descending order packs the ragged lanes across the mesh's
    lane shards so the per-device padding (every shard runs the group's
    common padded shapes) wastes the least work; arrival order used to put
    cheap lanes first and let one late expensive lane inflate the tail
    shard.

    Seed-invariant lanes (deterministic mappers — see `seed_invariant`)
    collapse their replicas onto a single simulated seed slot."""
    by_key: dict[tuple, list[int]] = {}
    for i in idxs:
        by_key.setdefault(scenarios[i].fold_key(), []).append(i)
    lanes = []
    for members in by_key.values():
        sc = scenarios[members[0]]
        if seed_invariant(sc):
            seeds = (sc.seed,)
            slots = (0,) * len(members)
        else:
            seeds = tuple(scenarios[i].seed for i in members)
            slots = tuple(range(len(members)))
        lanes.append(LanePlan(scenario=sc, seeds=seeds,
                              indices=tuple(members), slots=slots))
    lanes.sort(key=lambda lane: -lane_cost(lane))      # stable
    return lanes


def _pad_seed_axis(lanes: list[LanePlan]) -> tuple[list[LanePlan], int]:
    """Pad every lane's seed axis to the group max by repeating its first
    seed (padding slots re-simulate seeds[0]; their outputs are dropped)."""
    S = max(lane.n_seeds for lane in lanes)
    return [dataclasses.replace(
        lane, seeds=lane.seeds + (lane.seeds[0],) * (S - lane.n_seeds))
        for lane in lanes], S


def group_flags(group: Sequence[Scenario], cfg: NMPConfig,
                has_agent: bool) -> BodyFlags:
    """Static body flags for one sweep group: the OR over its lanes' needs."""
    pei_k = max((pei_top_k(sc.trace.n_pages, cfg) for sc in group
                 if sc.technique == "pei"), default=0)
    return BodyFlags(
        has_agent=has_agent,
        any_aimm=any(sc.mapper == "aimm" for sc in group),
        any_tom=any(sc.mapper == "tom" for sc in group),
        pei_k=pei_k,
        epoch_backend=epoch_ops.resolve_backend(),
    )


def _pad_to(n: int, d: int) -> int:
    return ((max(n, 1) + d - 1) // d) * d


def group_padded_cells(group: GroupPlan, lane_dim: int = 1,
                       seed_dim: int = 1) -> int:
    """Executed (lane, seed, episode) cell count of one group on a
    (lane_dim, seed_dim) device mesh, padding included."""
    return (_pad_to(group.n_lanes, lane_dim) * _pad_to(group.n_seeds, seed_dim)
            * group.n_episodes)


def packed_group_order(plan: GridPlan, lane_dim: int = 1,
                       seed_dim: int = 1) -> list[int]:
    """Execution order of a plan's groups: heaviest padded device cost
    first, stable.  Dispatching the big programs first overlaps their device
    execution with the host-side batch build of the cheap tail groups
    (run_grid pipelines prepare against compute), and plan.groups itself
    keeps the historical declaration order — only execution is reordered."""
    return sorted(range(len(plan.groups)),
                  key=lambda gi: -group_padded_cells(plan.groups[gi],
                                                     lane_dim, seed_dim))


def padding_waste(plan: GridPlan, lane_dim: int = 1,
                  seed_dim: int = 1) -> float:
    """Fraction of executed (lane, seed, episode) cells that are padding on
    a (lane_dim, seed_dim) mesh — the quantity `auto_mesh_shape` minimizes
    and BENCH_fleet.json records."""
    useful = sum(g.n_lanes * g.n_seeds * g.n_episodes for g in plan.groups)
    executed = sum(group_padded_cells(g, lane_dim, seed_dim)
                   for g in plan.groups)
    return 1.0 - useful / executed if executed else 0.0


@dataclasses.dataclass(frozen=True)
class Envelope:
    """The padded spatial/temporal envelope a grid's programs compile to.

    Normally derived from the scenarios themselves (`plan_envelope`); the
    serving layer (nmp.serving) instead *forces* one fixed envelope across
    every service tick, so the resident compiled programs' static shapes —
    and therefore the jit cache — never change as tenants come and go."""
    n_ops_max: int
    n_pages_max: int
    n_epochs: int
    ring_len: int
    n_episodes: int

    def dominates(self, other: "Envelope") -> bool:
        return (self.n_ops_max >= other.n_ops_max
                and self.n_pages_max >= other.n_pages_max
                and self.n_epochs >= other.n_epochs
                and self.ring_len >= other.ring_len
                and self.n_episodes >= other.n_episodes)


def plan_envelope(scenarios: Sequence[Scenario], cfg: NMPConfig) -> Envelope:
    """The minimal envelope covering every scenario of a grid."""
    if not scenarios:
        raise ValueError("empty scenario grid: plan_envelope needs at least "
                         "one scenario")
    return Envelope(
        n_ops_max=max(sc.trace.n_ops for sc in scenarios),
        n_pages_max=max(sc.trace.n_pages for sc in scenarios),
        n_epochs=max(serial_epochs(sc.trace.n_ops, cfg) for sc in scenarios),
        ring_len=max(phase_ring_len(sc.trace, cfg) for sc in scenarios),
        n_episodes=max(sc.total_episodes for sc in scenarios))


def plan_grid(scenarios: Sequence[Scenario], cfg: NMPConfig,
              envelope: Envelope | None = None) -> GridPlan:
    scenarios = tuple(scenarios)
    if not scenarios:
        raise ValueError(
            "empty scenario grid: run_grid/run_stream need at least one "
            "scenario per phase (got an empty sequence)")
    from repro.nmp.topology import validate_topology
    eff_topo = tuple(scenario_topology(sc, cfg) for sc in scenarios)
    for t in dict.fromkeys(eff_topo):
        validate_topology(t)
    # A lineage tag spanning topologies would compile into separate
    # per-topology programs whose final agents overwrite each other in the
    # PolicyStore (last group wins) — refuse it like the ragged-episode case
    # instead of corrupting the lineage (run per-topology phases as separate
    # run_grid calls, or use distinct tags).
    tag_topos: dict[str, set] = {}
    for i, sc in enumerate(scenarios):
        if lane_lineage(sc) is not None:
            tag_topos.setdefault(sc.lineage, set()).add(eff_topo[i])
    for tag, topos in tag_topos.items():
        if len(topos) > 1:
            raise ValueError(
                f"lineage {tag!r} spans topologies {sorted(topos)}; a tag's "
                "lanes must share one interconnect per grid (use distinct "
                "tags or separate run_grid calls)")

    # The spatial envelope (ops/pages/epochs/ring) is shared across both
    # agent-mode groups so the merged final_env and per-epoch timelines
    # stack; episode counts and seed widths are padded per group —
    # deterministic lanes must not simulate the AIMM lanes' longer training
    # schedules.  A forced `envelope` (the serving layer's fixed-shape
    # resident programs) replaces the derived one; it must dominate it, so
    # padding stays exact.
    derived = plan_envelope(scenarios, cfg)
    if envelope is not None:
        if not envelope.dominates(derived):
            raise ValueError(
                f"forced envelope {envelope} does not cover the grid's own "
                f"envelope {derived}; every scenario must fit the fixed "
                "shapes")
        env = envelope
    else:
        env = derived
    n_ops_max, n_pages_max = env.n_ops_max, env.n_pages_max
    n_epochs, ring_len = env.n_epochs, env.ring_len
    n_episodes = env.n_episodes

    # Group order: cold agent lanes first (the exact historical program),
    # then warm-capable lineage lanes, then deterministic lanes — grids
    # without lineages keep the historical two-group layout untouched.
    # Within an agent mode, lanes split further by cube topology (first-seen
    # order): interconnects differ in link count and routing tensors, so
    # each topology group compiles its own program; a single-topology grid
    # keeps the exact historical grouping.
    groups = []
    for has_agent, lineage in ((True, False), (True, True), (False, False)):
        mode_idxs = [i for i, sc in enumerate(scenarios)
                     if needs_agent(sc) == has_agent
                     and (lane_lineage(sc) is not None) == (has_agent
                                                            and lineage)]
        for topo in dict.fromkeys(eff_topo[i] for i in mode_idxs):
            idxs = [i for i in mode_idxs if eff_topo[i] == topo]
            lanes, n_seeds = _pad_seed_axis(_fold_lanes(scenarios, idxs))
            members = [scenarios[i] for i in idxs]
            group_eps = (envelope.n_episodes if envelope is not None
                         else max(sc.total_episodes for sc in members))
            if lineage:
                # Fail bad tags at plan time, not in the post-simulation
                # write-back (continual.check_tag enforces the same rule at
                # PolicyStore.put).
                from repro.nmp.continual import check_tag
                for sc in members:
                    check_tag(sc.lineage)
                # A padding episode would keep training a lineage's agent
                # past its scenario's schedule and hand the extra training to
                # the next phase — refuse ragged episode counts instead of
                # corrupting the lineage (run ragged phases as separate
                # run_grid calls).
                ragged = {sc.total_episodes for sc in members}
                if len(ragged) > 1:
                    raise ValueError(
                        "lineage lanes must share one episode count per grid "
                        f"(got {sorted(ragged)}); split ragged phases into "
                        "separate run_grid calls")
                if envelope is not None and ragged != {group_eps}:
                    raise ValueError(
                        f"lineage lanes run {sorted(ragged)} episodes but the "
                        f"forced envelope fixes {group_eps}; padding episodes "
                        "would keep training the lineage past its schedule")
            # Seed-invariant work sharing pays (and compiles in) only when
            # the simulated seed axis is wider than 1; the execute layer may
            # re-widen this after mesh padding (sweep.run_grid).
            flags = group_flags(members, cfg, has_agent)._replace(
                share_seed_inv=n_seeds > 1 and seed_share_enabled())
            groups.append(GroupPlan(
                lanes=tuple(lanes), has_agent=has_agent,
                flags=flags,
                n_episodes=group_eps,
                n_seeds=n_seeds, lineage=lineage, topology=topo))
    return GridPlan(scenarios=scenarios, groups=tuple(groups),
                    n_ops_max=n_ops_max, n_pages_max=n_pages_max,
                    n_epochs=n_epochs, ring_len=ring_len,
                    n_episodes=n_episodes,
                    agent_lineage=tuple(lane_lineage(sc) for sc in scenarios),
                    topologies=eff_topo)


def episode_schedule(sc: Scenario, seed: int,
                     n_episodes: int) -> tuple[np.ndarray, np.ndarray]:
    """(seeds, explore) per episode for one (lane, seed) cell, padded to the
    group episode count.

    Training episodes use seed, seed+1, ... (the run_program protocol); the
    optional eval episode replays the base seed with exploration off. Padding
    episodes continue the seed sequence and are simply not reported."""
    seeds = [seed + e for e in range(sc.episodes)]
    explore = [True] * sc.episodes
    if sc.eval_episode:
        seeds.append(seed)
        explore.append(False)
    while len(seeds) < n_episodes:
        seeds.append(seed + len(seeds))
        explore.append(True)
    return (np.asarray(seeds, np.int32), np.asarray(explore, bool))


def build_group_batch(plan: GridPlan, group: GroupPlan, cfg: NMPConfig,
                      host_cache: dict | None = None) -> dict[str, np.ndarray]:
    """Materialize one group's input batch as numpy arrays.

    Trace/ctx/page-table entries carry the lane axis (L, ...); the episode
    seed schedule carries the folded seed axis as (L, S, E) with the
    per-lane exploration schedule at (L, E) — seed replicas of a lane share
    the schedule *shape* by construction (fold_key includes episodes and
    eval_episode).

    `host_cache` (optional, caller-owned dict) memoizes the per-lane arrays
    across calls, keyed on everything that shapes them (fold key, envelope,
    episode count, seed axis, config).  The serving layer passes a
    per-server cache so each tick's host batch build reuses the padded trace
    ops / page tables / seed schedules of resident tenants instead of
    re-padding them every tick — only lanes new to the slot map are built."""
    lanes = []
    for lane in group.lanes:
        sc = lane.scenario
        key = (sc.fold_key(), plan.n_ops_max, plan.n_pages_max,
               group.n_episodes, lane.seeds, cfg)
        if host_cache is not None and key in host_cache:
            lanes.append(host_cache[key])
            continue
        tr = sc.trace
        ops = {k: np.asarray(v) for k, v in
               pad_trace_ops(tr, plan.n_ops_max, cfg).items()}
        pt = (np.asarray(sc.page_table, np.int32) if sc.page_table is not None
              else default_alloc(tr.n_pages, cfg))
        # pad the page table/RW flags with never-referenced filler pages that
        # follow the default interleave, so every entry is a legal cube id
        pad_pages = np.arange(tr.n_pages, plan.n_pages_max) % cfg.n_cubes
        pt = np.concatenate([pt, pad_pages.astype(np.int32)])
        rw = np.concatenate([tr.read_write,
                             np.zeros(plan.n_pages_max - tr.n_pages, bool)])
        ctx = make_ctx(tr, cfg, sc.technique, sc.mapper, sc.forced_action)
        scheds = [episode_schedule(sc, seed, group.n_episodes)
                  for seed in lane.seeds]
        built = {
            **ops, "page_table": pt, "rw": rw,
            "n_ops": np.int32(ctx.n_ops), "n_pages": np.int32(ctx.n_pages),
            "t_ring": np.int32(ctx.t_ring), "pei_idx": np.int32(ctx.pei_idx),
            "technique": np.int32(ctx.technique),
            "mapper": np.int32(ctx.mapper),
            "forced_action": np.int32(ctx.forced_action),
            "ep_seed": np.stack([s for s, _ in scheds]),       # (S, E)
            "ep_explore": scheds[0][1],                        # (E,)
        }
        if host_cache is not None:
            host_cache[key] = built
        lanes.append(built)
    return {k: np.stack([ln[k] for ln in lanes]) for k in lanes[0]}


def plan_tom_candidates(plan: GridPlan, cfg: NMPConfig):
    """TOM candidate tables for the plan's page envelope (shared, replicated
    across devices by the partition layer)."""
    return baselines.tom_candidates(plan.n_pages_max, cfg)
