"""Summary statistics & the paper's energy model (§7.7)."""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.nmp.config import ENERGY_NJ
from repro.nmp.engine import (EN_MDMA, EN_MEM_BITS, EN_MIG_Q, EN_NET_BIT_HOPS,
                              EN_NMP_BUF, EN_PAGE_CACHE, EN_REPLAY,
                              EN_STATE_BUF, EN_WEIGHT, EpisodeResult)


def summarize(res: EpisodeResult) -> dict[str, float]:
    env = res.env
    f = lambda x: float(np.asarray(x))
    cycles = max(f(env.cycles), 1.0)
    ops = f(env.ops_done)
    n_pages = env.mig_page_mask.shape[0]
    return {
        "cycles": cycles,
        "ops": ops,
        "opc": ops / cycles,
        "mean_hops": f(env.hops_sum) / max(ops, 1.0),
        "compute_util": f(env.util_sum) / max(f(env.epochs), 1.0),
        "migrations": f(env.mig_count),
        "frac_pages_migrated": f(env.mig_page_mask.sum()) / n_pages,
        "frac_access_migrated": f(env.access_on_migrated) / max(f(env.access_total), 1.0),
        "energy_nj": energy_nj(env.energy),
        "energy_breakdown": energy_breakdown(env.energy),
    }


def energy_breakdown(counters: jnp.ndarray) -> dict[str, float]:
    c = np.asarray(counters, np.float64)
    return {
        "aimm_hw": float(
            c[EN_PAGE_CACHE] * ENERGY_NJ["page_cache_access"]
            + c[EN_NMP_BUF] * ENERGY_NJ["nmp_buffer_access"]
            + c[EN_MIG_Q] * ENERGY_NJ["mig_queue_access"]
            + c[EN_MDMA] * ENERGY_NJ["mdma_access"]
            + c[EN_WEIGHT] * ENERGY_NJ["weight_access"]
            + c[EN_REPLAY] * ENERGY_NJ["replay_access"]
            + c[EN_STATE_BUF] * ENERGY_NJ["state_buffer_access"]),
        "network": float(c[EN_NET_BIT_HOPS] * ENERGY_NJ["network_per_bit_hop"]),
        "memory": float(c[EN_MEM_BITS] * ENERGY_NJ["memory_per_bit"]),
    }


def energy_nj(counters: jnp.ndarray) -> float:
    return float(sum(energy_breakdown(counters).values()))


def resample_opc(opc: np.ndarray, valid: np.ndarray,
                 samples: int = 64) -> np.ndarray:
    """Order-preserving fixed-size resample of the valid-epoch OPC series
    (the paper's Fig. 9 convention); shared by the serial and sweep paths."""
    opc = np.asarray(opc)[np.asarray(valid) > 0]
    if opc.size == 0:
        return np.zeros(samples)
    idx = np.linspace(0, opc.size - 1, samples).astype(int)
    return opc[idx]


def opc_timeline(res: EpisodeResult, samples: int = 64) -> np.ndarray:
    """Fixed-size resampled OPC timeline (paper Fig. 9 preserves order)."""
    return resample_opc(res.metrics["opc"], res.metrics["valid"], samples)
