"""Training step: chunked cross-entropy (big-vocab safe), z-loss, gradient
accumulation (microbatching via scan), optional int8 gradient compression for
the data-parallel reduction.

The LM head over a 262k vocabulary would materialize (B*S, V) logits; instead
the loss scans over token chunks, computing (chunk, V) logits transiently —
the standard big-vocab treatment (each chunk's logits live only inside the
scan body and its remat'd backward).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.train.compression import compress_decompress
from repro.train.optimizer import Optimizer

PyTree = Any
CE_CHUNK = 512


def chunked_ce_loss(model: Model, params, hidden, labels,
                    z_loss: float = 1e-4):
    """hidden: (B,S,D); labels: (B,S) with -100 = ignore. Mean CE over tokens."""
    B, S, D = hidden.shape
    V = model.cfg.padded_vocab
    T = B * S
    chunk = min(CE_CHUNK, T)
    n_chunks = T // chunk
    hf = hidden.reshape(T, D)[: n_chunks * chunk].reshape(n_chunks, chunk, D)
    lf = labels.reshape(T)[: n_chunks * chunk].reshape(n_chunks, chunk)

    @jax.checkpoint          # recompute chunk logits in backward: the scan
    def body(carry, inp):    # would otherwise save (chunk, V) residuals/step
        loss_sum, z_sum, count = carry
        h, l = inp
        logits = model.logits(params, h).astype(jnp.float32)     # (chunk, V)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, jnp.maximum(l, 0)[:, None],
                                  axis=1)[:, 0]
        mask = (l >= 0).astype(jnp.float32)
        loss_sum = loss_sum + jnp.sum((lse - tgt) * mask)
        z_sum = z_sum + jnp.sum(jnp.square(lse) * mask)
        return (loss_sum, z_sum, count + jnp.sum(mask)), None

    init = (jnp.zeros(()), jnp.zeros(()), jnp.zeros(()))
    (loss_sum, z_sum, count), _ = jax.lax.scan(body, init, (hf, lf))
    count = jnp.maximum(count, 1.0)
    return loss_sum / count + z_loss * z_sum / count


def make_loss_fn(model: Model, z_loss: float = 1e-4,
                 lb_coef: float = 1e-2) -> Callable:
    def loss_fn(params, batch):
        hidden, aux = model.apply(params, batch)
        loss = chunked_ce_loss(model, params, hidden, batch["labels"], z_loss)
        if model.cfg.moe is not None:
            loss = loss + lb_coef * aux.get("lb_loss", 0.0) / max(
                model.cfg.n_layers, 1)
        return loss, aux

    return loss_fn


def make_train_step(model: Model, opt: Optimizer, microbatches: int = 1,
                    grad_compression: str = "none",
                    grad_shardings: Any = None,
                    batch_shardings: Any = None) -> Callable:
    """Returns train_step(params, opt_state, batch, step) -> (params,
    opt_state, metrics).

    microbatches > 1: the global batch is split on axis 0 and gradients are
    accumulated with a scan — activation memory drops by the microbatch factor
    while keeping the same mathematical batch.
    grad_compression 'int8': gradients pass through blockwise int8
    quantize/dequantize with error feedback carried in opt-state-adjacent
    buffers omitted here (stateless EF within the step); models the wire
    format of a compressed all-reduce.
    grad_shardings: param-sharding pytree; the fp32 grad accumulator is
    constrained to it (otherwise GSPMD may leave the accumulator replicated —
    a 4*N-byte temp).
    """
    loss_fn = make_loss_fn(model)

    def constrain(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(jax.lax.with_sharding_constraint, grads,
                            grad_shardings)

    def compute_grads(params, batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                       batch)
        return loss, grads

    def train_step(params, opt_state, batch, step):
        if microbatches > 1:
            def split(x):
                B = x.shape[0]
                assert B % microbatches == 0
                return x.reshape((microbatches, B // microbatches) + x.shape[1:])

            mb = jax.tree.map(split, batch)

            def body(carry, mb_batch):
                loss_acc, grads_acc = carry
                if batch_shardings is not None:
                    # the (mb, B/mb, ...) reshape confuses GSPMD propagation;
                    # re-pin each microbatch to the batch sharding
                    mb_batch = {
                        k: jax.lax.with_sharding_constraint(
                            v, batch_shardings[k])
                        for k, v in mb_batch.items()}
                loss, grads = compute_grads(params, mb_batch)
                return (loss_acc + loss,
                        constrain(jax.tree.map(jnp.add, grads_acc, grads))), None

            zeros = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            loss, grads = compute_grads(params, batch)
            grads = constrain(grads)

        if grad_compression == "int8":
            grads = jax.tree.map(compress_decompress, grads)

        new_params, new_opt = opt.update(grads, opt_state, params, step)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

    return train_step
