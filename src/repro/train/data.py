"""Deterministic synthetic LM data pipeline (multi-host ready).

Tokens are a stateless hash of (seed, step, position) so any host can
materialize exactly its shard of any step without coordination — the property
a 1000-node data pipeline needs for deterministic restart after failure
(resume at step k reproduces the same global batch bit-for-bit).

The stream has learnable structure (a periodic Markov-ish mix), so small-model
training loss decreases visibly in the e2e example.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 0
    structure: int = 97          # period of the learnable component


def _hash(x: np.ndarray) -> np.ndarray:
    x = (x ^ (x >> 16)) * np.uint64(0x45d9f3b)
    x = (x ^ (x >> 16)) * np.uint64(0x45d9f3b)
    return x ^ (x >> 16)


def global_batch_np(cfg: DataConfig, step: int) -> np.ndarray:
    """The full (B, S+1) token block for `step` (labels = tokens shifted)."""
    B, S = cfg.global_batch, cfg.seq + 1
    idx = np.arange(B * S, dtype=np.uint64).reshape(B, S)
    base = _hash(idx + np.uint64(step * 1_000_003 + cfg.seed * 7_777_777))
    noise = (base % np.uint64(cfg.vocab)).astype(np.int64)
    # learnable structure: token ~ f(position mod structure) most of the time
    pos = np.arange(S, dtype=np.int64)[None, :] % cfg.structure
    pattern = (pos * 31 + 7) % cfg.vocab
    use_pattern = (base >> np.uint64(32)) % np.uint64(4) != 0   # 75% pattern
    return np.where(use_pattern, pattern, noise).astype(np.int32)


def host_shard(cfg: DataConfig, step: int, host_id: int, n_hosts: int
               ) -> np.ndarray:
    """This host's rows of the global batch (contiguous row sharding)."""
    assert cfg.global_batch % n_hosts == 0
    per = cfg.global_batch // n_hosts
    full = global_batch_np(cfg, step)
    return full[host_id * per:(host_id + 1) * per]


class SyntheticDataset:
    """Iterator over (tokens, labels) batches; deterministic in (seed, step)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 host_id: int = 0, n_hosts: int = 1):
        self.cfg = cfg
        self.step = start_step
        self.host_id = host_id
        self.n_hosts = n_hosts

    def __iter__(self):
        return self

    def __next__(self):
        block = host_shard(self.cfg, self.step, self.host_id, self.n_hosts)
        self.step += 1
        return {"tokens": jnp.asarray(block[:, :-1]),
                "labels": jnp.asarray(block[:, 1:])}

    def state(self):
        return {"step": self.step}

    def restore(self, state):
        self.step = int(state["step"])
