"""Training/serving substrate: optimizers, steps, data, checkpoints, loops."""
from repro.train.optimizer import adamw, quantized_adamw, sgd  # noqa: F401
from repro.train.train_step import make_train_step, make_loss_fn  # noqa: F401
