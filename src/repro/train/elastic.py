"""Elastic mesh management + straggler mitigation.

At 1000+ nodes, device failures are routine: the control plane must (a) pick a
working mesh from whatever devices remain, (b) reshard the checkpointed state
onto it, (c) keep the data pipeline deterministic across the resize. The mesh
refactorization here is pure logic (tested on CPU with forced device counts);
the restore path is CheckpointManager.restore(shardings=...).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np


def factor_mesh(n_devices: int, model_parallel: int,
                prefer_pods: int = 1) -> tuple[int, ...] | None:
    """Choose (pod, data, model) given a device count and a fixed TP degree.

    TP (model) stays fixed across resizes — param shardings survive — while
    the data axis absorbs the lost nodes. Returns None if n_devices doesn't
    support the TP degree.
    """
    if n_devices % model_parallel:
        return None
    rest = n_devices // model_parallel
    pods = prefer_pods
    while pods > 1 and rest % pods:
        pods -= 1
    return (pods, rest // pods, model_parallel)


def largest_viable_mesh(n_devices: int, model_parallel: int,
                        batch_divisor: int) -> tuple[int, ...] | None:
    """Largest mesh (<= n_devices) whose data axis divides the global batch."""
    for n in range(n_devices, model_parallel - 1, -1):
        shape = factor_mesh(n, model_parallel)
        if shape is None:
            continue
        _, data, _ = shape
        if batch_divisor % data == 0:
            return shape
    return None


@dataclasses.dataclass
class StragglerWatchdog:
    """Tracks per-step wall times; flags steps slower than `factor` x the
    rolling median so the control plane can reroute / recompile / evict.
    """
    factor: float = 2.0
    window: int = 32
    times: list = dataclasses.field(default_factory=list)
    flagged: int = 0

    def observe(self, step_time: float) -> bool:
        med = float(np.median(self.times[-self.window:])) if self.times else None
        self.times.append(step_time)
        if med is not None and step_time > self.factor * med:
            self.flagged += 1
            return True
        return False

    @property
    def median(self) -> float:
        return float(np.median(self.times[-self.window:])) if self.times else 0.0


class SimulatedFailures:
    """Deterministic failure injector for tests/examples: raises at the given
    steps, once each (models a node loss the loop must survive)."""

    def __init__(self, fail_at: tuple[int, ...] = ()):
        self.fail_at = set(fail_at)

    def check(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise RuntimeError(f"injected node failure at step {step}")
