"""Sharded checkpointing with atomic commits, retention, resharding restore,
and async writes — the fault-tolerance substrate for the train loop.

Layout:
  <dir>/step_<k>.tmp/...   while writing
  <dir>/step_<k>/          after atomic rename (commit point)
      meta.json            tree structure, shapes, dtypes, step, extras
      shard_<i>.npz        leaf arrays (one file per host in multi-host runs)

Restore maps saved leaves back onto the requested shardings via
`jax.device_put`, so a checkpoint written on one mesh restores onto another
(elastic resize / failure-driven re-mesh).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- write ----------------------------------------------------------
    def save(self, step: int, tree: PyTree, extras: dict | None = None,
             host_id: int = 0):
        arrays = {k: np.asarray(v) for k, v in _leaf_paths(tree)}
        meta = {
            "step": step,
            "extras": extras or {},
            "leaves": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                       for k, a in arrays.items()},
        }
        self.wait()
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, arrays, meta, host_id))
            self._thread.start()
        else:
            self._write(step, arrays, meta, host_id)

    def _write(self, step, arrays, meta, host_id):
        tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
        final = os.path.join(self.dir, f"step_{step:09d}")
        os.makedirs(tmp, exist_ok=True)
        # bf16 has no numpy dtype; store as uint16 view + dtype tag
        store = {}
        for k, a in arrays.items():
            if a.dtype == jnp.bfloat16:
                store[k] = a.view(np.uint16)
                meta["leaves"][k]["dtype"] = "bfloat16"
            else:
                store[k] = a
        np.savez(os.path.join(tmp, f"shard_{host_id}.npz"), **store)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)           # atomic commit
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # -- read -----------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def read_meta(self, step: int | None = None) -> dict:
        """Checkpoint metadata (step, extras, per-leaf shapes/dtypes) without
        loading any arrays.  Restore targets whose tree *structure* is data-
        dependent (e.g. a PolicyStore's tag -> agent map) read this first to
        build the template `restore` maps leaves onto."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        with open(os.path.join(self.dir, f"step_{step:09d}",
                               "meta.json")) as f:
            return json.load(f)

    def restore(self, template: PyTree, step: int | None = None,
                shardings: PyTree | None = None, host_id: int = 0
                ) -> tuple[PyTree, dict]:
        """Restore onto `template`'s structure; place per `shardings` if given
        (resharding restore for elastic meshes)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(path, f"shard_{host_id}.npz"))
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_flat = (jax.tree.leaves(shardings)
                      if shardings is not None else [None] * len(flat))
        leaves = []
        for (p, leaf), sh in zip(flat, shard_flat):
            key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                           for q in p)
            a = data[key]
            if meta["leaves"][key]["dtype"] == "bfloat16":
                a = a.view(jnp.bfloat16)
            if sh is not None:
                leaves.append(jax.device_put(a, sh))
            else:
                leaves.append(jnp.asarray(a))
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, {"step": meta["step"], **meta["extras"]}
