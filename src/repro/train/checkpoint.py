"""Sharded checkpointing with atomic commits, retention, resharding restore,
async writes, and corruption-detecting restore — the fault-tolerance
substrate for the train loop and the continual-learning PolicyStore.

Layout:
  <dir>/step_<k>.tmp/...   while writing
  <dir>/step_<k>/          after atomic rename (commit point)
      meta.json            tree structure, shapes, dtypes, checksums, extras
      shard_<i>.npz        leaf arrays (one file per host in multi-host runs)

Crash safety: every file is flushed and fsync'd before the tmp directory is
renamed over the final name (and the parent directory fsync'd after), so a
process killed at ANY byte boundary leaves either no `step_<k>` directory or
a complete one — never a torn commit.  Each leaf's crc32 is recorded in
`meta.json`; `restore` verifies leaves against it and, when no explicit step
was requested, falls back to the newest *intact* step (raising
`CheckpointCorruptError` only when an explicitly named step is bad or no
intact step exists).

Restore maps saved leaves back onto the requested shardings via
`jax.device_put`, so a checkpoint written on one mesh restores onto another
(elastic resize / failure-driven re-mesh).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


class CheckpointCorruptError(RuntimeError):
    """A checkpoint step failed integrity verification (unreadable meta or
    shard, missing leaf, or per-leaf checksum mismatch)."""


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:                      # pragma: no cover - exotic fs
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def decode_leaf(a: np.ndarray, dtype_str: str):
    """Undo the on-disk encoding of one leaf (bf16 is stored as a uint16
    view + dtype tag, since numpy has no native bfloat16)."""
    return a.view(jnp.bfloat16) if dtype_str == "bfloat16" else a


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        self._exc: BaseException | None = None
        os.makedirs(directory, exist_ok=True)

    # -- write ----------------------------------------------------------
    def save(self, step: int, tree: PyTree, extras: dict | None = None,
             host_id: int = 0):
        arrays = {k: np.asarray(v) for k, v in _leaf_paths(tree)}
        meta = {
            "step": step,
            "extras": extras or {},
            "leaves": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                       for k, a in arrays.items()},
        }
        self.wait()
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, arrays, meta, host_id))
            self._thread.start()
        else:
            self._write(step, arrays, meta, host_id)

    def _write_guarded(self, *args):
        try:
            self._write(*args)
        except BaseException as e:       # re-raised by wait()
            self._exc = e

    def _write(self, step, arrays, meta, host_id):
        tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
        final = os.path.join(self.dir, f"step_{step:09d}")
        if os.path.exists(tmp):          # stale tmp from a killed writer
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        # bf16 has no numpy dtype; store as uint16 view + dtype tag
        store = {}
        for k, a in arrays.items():
            if a.dtype == jnp.bfloat16:
                store[k] = a.view(np.uint16)
                meta["leaves"][k]["dtype"] = "bfloat16"
            else:
                store[k] = a
            meta["leaves"][k]["crc32"] = zlib.crc32(store[k].tobytes())
        shard = os.path.join(tmp, f"shard_{host_id}.npz")
        np.savez(shard, **store)
        _fsync_file(shard)
        meta_path = os.path.join(tmp, "meta.json")
        with open(meta_path, "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        _fsync_dir(tmp)
        if os.path.exists(final):
            # overwrite (resume-from-older-step rewrites stale later steps);
            # a kill between these two calls loses only the stale step —
            # restore falls back to the next newest intact one.
            shutil.rmtree(final)
        os.rename(tmp, final)           # atomic commit
        _fsync_dir(self.dir)
        self._gc()

    def wait(self):
        """Block until the in-flight async write finishes.  Re-raises the
        writer's exception if it failed, so a failed save cannot masquerade
        as success."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # -- read -----------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if (d.startswith("step_") and not d.endswith(".tmp")
                    and d.split("_", 1)[1].isdigit()):
                out.append(int(d.split("_", 1)[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def read_meta(self, step: int | None = None) -> dict:
        """Checkpoint metadata (step, extras, per-leaf shapes/dtypes/crcs)
        without loading any arrays.  Restore targets whose tree *structure*
        is data-dependent (e.g. a PolicyStore's tag -> agent map) read this
        first to build the template `restore` maps leaves onto.  Raises
        `CheckpointCorruptError` on unreadable/garbage metadata."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(
                f"no checkpoints in {self.dir!r}: the directory holds no "
                "committed step_<k> entries (nothing was ever saved here, "
                "or every save was torn before its atomic commit)")
        path = os.path.join(self.dir, f"step_{step:09d}", "meta.json")
        try:
            with open(path) as f:
                meta = json.load(f)
        except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
            raise CheckpointCorruptError(
                f"unreadable checkpoint metadata {path}: {e}") from e
        if not isinstance(meta, dict) or "leaves" not in meta:
            raise CheckpointCorruptError(
                f"malformed checkpoint metadata {path}")
        return meta

    def load_arrays(self, step: int, host_id: int = 0
                    ) -> tuple[dict, dict, set[str]]:
        """Load one step's raw (still-encoded) arrays with integrity checks.

        Returns `(arrays, meta, bad_keys)` where `bad_keys` holds every leaf
        that is missing, unreadable, or fails its recorded crc32.  Raises
        `CheckpointCorruptError` only when the step is unreadable as a whole
        (garbage meta, missing/unopenable shard file)."""
        meta = self.read_meta(step)
        path = os.path.join(self.dir, f"step_{step:09d}",
                            f"shard_{host_id}.npz")
        try:
            data = np.load(path)
        except Exception as e:
            raise CheckpointCorruptError(
                f"unreadable checkpoint shard {path}: {e}") from e
        arrays: dict[str, np.ndarray] = {}
        bad: set[str] = set()
        try:
            for key, rec in meta["leaves"].items():
                try:
                    a = data[key]
                except Exception:
                    bad.add(key)
                    continue
                crc = rec.get("crc32")
                if crc is not None and zlib.crc32(a.tobytes()) != crc:
                    bad.add(key)
                    continue
                arrays[key] = a
        finally:
            data.close()
        return arrays, meta, bad

    def verify(self, step: int, host_id: int = 0) -> bool:
        """True iff every leaf of `step` loads and matches its checksum."""
        try:
            _, _, bad = self.load_arrays(step, host_id)
        except (CheckpointCorruptError, FileNotFoundError):
            return False
        return not bad

    def newest_intact_step(self, host_id: int = 0) -> int | None:
        for s in reversed(self.all_steps()):
            if self.verify(s, host_id):
                return s
        return None

    def restore(self, template: PyTree, step: int | None = None,
                shardings: PyTree | None = None, host_id: int = 0
                ) -> tuple[PyTree, dict]:
        """Restore onto `template`'s structure; place per `shardings` if given
        (resharding restore for elastic meshes).

        An explicitly requested corrupt `step` raises
        `CheckpointCorruptError`.  With `step=None`, corrupt steps are
        skipped newest-first until an intact one restores (the count of
        skipped steps is reported as `fallback_steps_skipped` in the
        returned info dict)."""
        explicit = step is not None
        steps = [step] if explicit else list(reversed(self.all_steps()))
        if not steps:
            raise FileNotFoundError(
                f"no checkpoints in {self.dir!r}: the directory holds no "
                "committed step_<k> entries")
        skipped = 0
        last_err: Exception | None = None
        for s in steps:
            try:
                tree, info = self._restore_step(template, s, shardings,
                                                host_id)
                info["fallback_steps_skipped"] = skipped
                return tree, info
            except CheckpointCorruptError as e:
                if explicit:
                    raise
                skipped += 1
                last_err = e
        raise CheckpointCorruptError(
            f"no intact checkpoint step in {self.dir!r} "
            f"({skipped} corrupt step(s) skipped): {last_err}")

    def _restore_step(self, template: PyTree, step: int, shardings,
                      host_id: int) -> tuple[PyTree, dict]:
        arrays, meta, bad = self.load_arrays(step, host_id)
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_flat = (jax.tree.leaves(shardings)
                      if shardings is not None else [None] * len(flat))
        leaves = []
        for (p, leaf), sh in zip(flat, shard_flat):
            key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                           for q in p)
            if key in bad or key not in arrays:
                raise CheckpointCorruptError(
                    f"checkpoint step {step} leaf {key!r} is missing or "
                    "fails its checksum")
            a = decode_leaf(arrays[key], meta["leaves"][key]["dtype"])
            if sh is not None:
                leaves.append(jax.device_put(a, sh))
            else:
                leaves.append(jnp.asarray(a))
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, {"step": meta["step"], **meta["extras"]}
