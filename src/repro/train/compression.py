"""Gradient compression for data-parallel reductions.

int8 blockwise quantization models the wire format of a compressed all-reduce:
on real hardware the reduce-scatter runs on int8 payloads + fp32 block scales
(4x less DP traffic); here the quantize->dequantize round trip is applied to
the gradients so convergence behaviour (and tests) see the true quantization
error. Top-k sparsification with error feedback is provided for the
bandwidth-starved multi-pod DP axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_BLOCK = 256


def compress_decompress(g: jnp.ndarray) -> jnp.ndarray:
    """int8 blockwise quantize->dequantize (symmetric, per-256-block scales)."""
    if g.size < _BLOCK:
        return g
    orig_dtype = g.dtype
    n = g.size
    pad = (-n) % _BLOCK
    flat = jnp.pad(g.astype(jnp.float32).reshape(-1), (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127)
    out = (q * scale).reshape(-1)[:n].reshape(g.shape)
    return out.astype(orig_dtype)


def topk_with_error_feedback(g: jnp.ndarray, residual: jnp.ndarray,
                             frac: float = 0.01):
    """Keep the top-`frac` magnitude entries of (g + residual); the rest feeds
    back into `residual` (memory-augmented sparsification)."""
    acc = g.astype(jnp.float32) + residual
    k = max(int(g.size * frac), 1)
    flat = acc.reshape(-1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = (jnp.abs(flat) >= thresh).astype(jnp.float32)
    sent = flat * mask
    new_residual = (flat - sent).reshape(g.shape)
    return sent.reshape(g.shape).astype(g.dtype), new_residual
