"""Fault-tolerant training loop.

Wraps the jitted train step with: periodic + emergency checkpointing, restart
from the latest commit on failure, straggler flagging, and deterministic data
resume. This is the control plane a multi-pod run needs; failures are injected
in tests via elastic.SimulatedFailures.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax

from repro.train.checkpoint import CheckpointManager
from repro.train.data import SyntheticDataset
from repro.train.elastic import SimulatedFailures, StragglerWatchdog


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    max_restarts: int = 3
    log_every: int = 10


def train_loop(train_step: Callable, params, opt_state, dataset:
               SyntheticDataset, cfg: LoopConfig,
               failures: SimulatedFailures | None = None,
               log: Callable = print) -> dict:
    """Runs to cfg.total_steps, surviving injected failures via restart from
    the last committed checkpoint. Returns final state + stats."""
    ckpt = CheckpointManager(cfg.checkpoint_dir, keep=cfg.keep)
    watchdog = StragglerWatchdog()
    restarts = 0
    step = 0
    losses = []

    # resume if a checkpoint exists
    latest = ckpt.latest_step()
    if latest is not None:
        (params, opt_state), extras = ckpt.restore((params, opt_state))
        step = extras["step"]
        dataset.restore({"step": extras.get("data_step", step)})
        log(f"[loop] resumed from step {step}")

    import jax.numpy as jnp

    while step < cfg.total_steps:
        try:
            batch = next(dataset)
            if failures is not None:
                failures.check(step)
            t0 = time.time()
            params, opt_state, metrics = train_step(
                params, opt_state, batch, jnp.asarray(step))
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            slow = watchdog.observe(dt)
            if slow:
                log(f"[loop] straggler flagged at step {step}: "
                    f"{dt:.3f}s vs median {watchdog.median:.3f}s")
            losses.append(float(metrics["loss"]))
            if step % cfg.log_every == 0:
                log(f"[loop] step {step} loss {float(metrics['loss']):.4f} "
                    f"({dt*1e3:.0f} ms)")
            step += 1
            if step % cfg.checkpoint_every == 0:
                ckpt.save(step, (params, opt_state),
                          extras={"data_step": dataset.state()["step"]})
        except RuntimeError as e:
            restarts += 1
            log(f"[loop] FAILURE: {e} -> restart {restarts}/{cfg.max_restarts}")
            if restarts > cfg.max_restarts:
                raise
            ckpt.wait()
            latest = ckpt.latest_step()
            if latest is not None:
                (params, opt_state), extras = ckpt.restore((params, opt_state))
                step = extras["step"]
                dataset.restore({"step": extras.get("data_step", step)})
            else:
                step = 0
                dataset.restore({"step": 0})

    ckpt.wait()
    ckpt.save(step, (params, opt_state),
              extras={"data_step": dataset.state()["step"]})
    ckpt.wait()
    return {"params": params, "opt_state": opt_state, "step": step,
            "losses": losses, "restarts": restarts,
            "stragglers": watchdog.flagged}
