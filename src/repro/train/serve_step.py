"""Serving: batched prefill + decode with KV caches and simple continuous
batching (slot-based request admission)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model import Model


def make_serve_step(model: Model) -> Callable:
    """serve_step(params, token, caches, position) -> (next_token, caches).

    Greedy decode of one token for the whole batch; the jitted unit the decode
    dry-run cells lower.
    """
    def serve_step(params, token, caches, position):
        logits, caches = model.decode_step(params, token, caches, position)
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return nxt[:, None].astype(jnp.int32), caches

    return serve_step


def sample_token(logits, rng, temperature: float = 1.0, top_k: int = 0):
    """Temperature + top-k sampling (fp32)."""
    lg = logits.astype(jnp.float32) / max(temperature, 1e-5)
    if top_k:
        kth = jax.lax.top_k(lg, top_k)[0][..., -1:]
        lg = jnp.where(lg < kth, -1e9, lg)
    return jax.random.categorical(rng, lg, axis=-1)


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new: int = 32
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchServer:
    """Minimal continuous-batching server: fixed B slots, per-slot position,
    prefill via teacher-forced decode, greedy generation."""

    def __init__(self, model: Model, params, batch: int, max_seq: int):
        self.model = model
        self.params = params
        self.B = batch
        self.max_seq = max_seq
        self.caches = model.init_caches(batch, max_seq)
        self.positions = [0] * batch
        self.slots: list[Request | None] = [None] * batch
        self._step = jax.jit(make_serve_step(model))
        self._decode = jax.jit(model.decode_step)

    def admit(self, req: Request) -> bool:
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = req
                self.positions[i] = 0
                return True
        return False

    def _tokens_now(self):
        toks = []
        for i, s in enumerate(self.slots):
            if s is None:
                toks.append(0)
            elif self.positions[i] < len(s.prompt):
                toks.append(s.prompt[self.positions[i]])
            else:
                toks.append(s.generated[-1] if s.generated else s.prompt[-1])
        return jnp.asarray(toks, jnp.int32)[:, None]

    def step(self):
        """One lockstep decode across slots (batch shares a position counter in
        this minimal variant: positions advance together; prompts left-pad)."""
        pos = max(self.positions)
        token = self._tokens_now()
        logits, self.caches = self._decode(self.params, token, self.caches,
                                           jnp.asarray(pos, jnp.int32))
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            self.positions[i] += 1
            if self.positions[i] >= len(s.prompt):
                s.generated.append(int(nxt[i]))
                if len(s.generated) >= s.max_new:
                    s.done = True
                    self.slots[i] = None
        return nxt
