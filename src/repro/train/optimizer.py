"""Optimizers in pure JAX (no optax dependency).

Provides:
  - adamw(lr, ...)            -> standard AdamW with optional cosine schedule
  - quantized_adamw(...)      -> AdamW with int8 blockwise-quantized moments
                                 (distributed-optimization trick: 4x optimizer-state
                                 memory reduction, needed to fit jamba-398B per-chip HBM)
  - sgd(lr)                   -> plain SGD (used by tests)

All optimizers follow the (init_fn, update_fn) protocol:
    state = init_fn(params)
    new_params, new_state = update_fn(grads, state, params, step)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, jnp.ndarray], tuple[PyTree, PyTree]]


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def constant_schedule(lr: float) -> Callable[[jnp.ndarray], jnp.ndarray]:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = lr * jnp.minimum(step / max(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return sched


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(
    lr: float | Callable = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip: float = 0.0,
) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if grad_clip > 0:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        t = step.astype(jnp.float32) + 1.0
        lr_t = sched(step)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(p, g, m, v):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        new_p = jax.tree.unflatten(treedef, [l[0] for l in leaves])
        new_m = jax.tree.unflatten(treedef, [l[1] for l in leaves])
        new_v = jax.tree.unflatten(treedef, [l[2] for l in leaves])
        return new_p, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Int8 blockwise-quantized AdamW (optimizer-state compression)
# ---------------------------------------------------------------------------

_QBLOCK = 256


def quantizable(shape) -> bool:
    """Blockwise-int8 eligible: last dim divisible by the block size. The
    last-dim split is a *local* reshape, so sharding on every other dim is
    preserved under SPMD (a flatten+pad would force replicated intermediates)."""
    return len(shape) >= 1 and shape[-1] % _QBLOCK == 0


def _q8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise symmetric int8 quantization along the last dim.
    x: (..., F) -> q (..., F/B, B) int8, scale (..., F/B) f32."""
    F = x.shape[-1]
    xb = x.reshape(*x.shape[:-1], F // _QBLOCK, _QBLOCK)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    x = q.astype(jnp.float32) * scale[..., None]
    return x.reshape(shape)


_VLOG_FLOOR = 1e-16


def _q8_log(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Blockwise asymmetric int8 quantization in log space, for the
    non-negative second moment: symmetric linear quantization would zero out
    small entries and explode 1/sqrt(v) steps. x: (..., F) >= 0."""
    F = x.shape[-1]
    lx = jnp.log(x.reshape(*x.shape[:-1], F // _QBLOCK, _QBLOCK)
                 + _VLOG_FLOOR)
    lo = jnp.min(lx, axis=-1)
    hi = jnp.max(lx, axis=-1)
    scale = (hi - lo) / 254.0 + 1e-12
    q = jnp.clip(jnp.round((lx - lo[..., None]) / scale[..., None]) - 127,
                 -127, 127).astype(jnp.int8)
    return q, lo.astype(jnp.float32), scale.astype(jnp.float32)


def _dq8_log(q, lo, scale, shape) -> jnp.ndarray:
    lx = (q.astype(jnp.float32) + 127.0) * scale[..., None] + lo[..., None]
    return (jnp.exp(lx) - _VLOG_FLOOR).clip(min=0.0).reshape(shape)


def quantized_adamw(
    lr: float | Callable = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip: float = 0.0,
) -> Optimizer:
    """AdamW whose m/v moments are stored as blockwise int8 (+fp32 scales).

    State per tensor: {mq, ms, vq, vs} when the last dim divides the block
    size, else plain fp32 {m, v} (small leaves). Dequantize -> update ->
    requantize each step; error bounded by the per-block scale (<= 0.8%
    relative), standard 8-bit-optimizer behaviour.
    """
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        def one(p):
            if quantizable(p.shape):
                q, s = _q8(jnp.zeros(p.shape, jnp.float32))
                vq, vlo, vsc = _q8_log(jnp.zeros(p.shape, jnp.float32))
                return {"mq": q, "ms": s, "vq": vq, "v_lo": vlo, "v_sc": vsc}
            z = jnp.zeros(p.shape, jnp.float32)
            return {"m": z, "v": z}

        return jax.tree.map(one, params)

    def update(grads, state, params, step):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if grad_clip > 0:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        t = step.astype(jnp.float32) + 1.0
        lr_t = sched(step)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(p, g, st):
            quant = "mq" in st
            if quant:
                m = _dq8(st["mq"], st["ms"], p.shape)
                v = _dq8_log(st["vq"], st["v_lo"], st["v_sc"], p.shape)
            else:
                m, v = st["m"], st["v"]
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            delta = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)
            if quant:
                mq, ms = _q8(m)
                vq, vlo, vsc = _q8_log(v)
                return newp, {"mq": mq, "ms": ms, "vq": vq, "v_lo": vlo,
                              "v_sc": vsc}
            return newp, {"m": m, "v": v}

        out = jax.tree.map(upd, params, grads, state,
                           is_leaf=lambda x: isinstance(x, dict) and
                           ("mq" in x or "m" in x))
        # out mirrors params-tree with (newp, newstate) tuples at leaves
        leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        new_p = jax.tree.unflatten(treedef, [l[0] for l in leaves])
        new_s = jax.tree.unflatten(treedef, [l[1] for l in leaves])
        return new_p, new_s

    return Optimizer(init, update)


def sgd(lr: float = 1e-2) -> Optimizer:
    def init(params):
        return {}

    def update(grads, state, params, step):
        new_p = jax.tree.map(lambda p, g: (p - lr * g.astype(p.dtype)).astype(p.dtype), params, grads)
        return new_p, state

    return Optimizer(init, update)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))
