"""Production training driver.

Wires an assigned architecture to the sharded train step, data pipeline and
fault-tolerant loop on whatever mesh the host exposes. On the CPU container
this runs reduced (smoke) configs; on a real pod the same entry point takes
the full configs (the dry-run proves they lower and fit).

    python -m repro.launch.train --arch minitron-8b --smoke --steps 50
"""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--data-parallel", type=int, default=0,
                    help="0 = all visible devices")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--quantized-opt", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model, count_params
    from repro.models.model import abstract_init
    from repro.sharding import policies
    from repro.train.data import DataConfig, SyntheticDataset
    from repro.train.loop import LoopConfig, train_loop
    from repro.train.optimizer import adamw, quantized_adamw
    from repro.train.train_step import make_train_step

    cfg = get_config(args.arch, smoke=args.smoke)
    n_dev = len(jax.devices())
    dp = args.data_parallel or max(n_dev // args.model_parallel, 1)
    mesh = make_host_mesh(dp, args.model_parallel)
    print(f"[train] arch={cfg.name} params={count_params(cfg)/1e6:.1f}M "
          f"mesh={dict(mesh.shape)}")

    model = build_model(cfg)
    params, roles = model.init(jax.random.PRNGKey(0))
    opt = (quantized_adamw if args.quantized_opt else adamw)(
        1e-3, weight_decay=0.01, grad_clip=1.0)
    opt_state = opt.init(params)

    pshapes, _ = abstract_init(model)
    pspecs = policies.param_specs(roles, pshapes, cfg, mesh)
    with mesh:
        params = jax.tree.map(
            lambda p, s: jax.device_put(p, s), params, pspecs)
        step = jax.jit(make_train_step(model, opt,
                                       microbatches=args.microbatches,
                                       grad_shardings=pspecs))
        data = SyntheticDataset(DataConfig(vocab=cfg.vocab, seq=args.seq,
                                           global_batch=args.global_batch))
        res = train_loop(step, params, opt_state, data,
                         LoopConfig(total_steps=args.steps,
                                    checkpoint_every=max(args.steps // 2, 10),
                                    checkpoint_dir=args.ckpt_dir,
                                    log_every=10))
    print(f"[train] final loss {res['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
