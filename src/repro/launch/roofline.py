"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Three terms per (arch x shape x mesh):

  compute_s    = HLO_FLOPs / (chips * 197e12)          bf16 peak per chip
  memory_s     = HLO_bytes / (chips * 819e9)           HBM bandwidth
  collective_s = collective_bytes / (chips * 50e9)     ICI per link

HLO sources:
  - compiled.cost_analysis() gives flops / bytes accessed, but counts each
    `while` (lax.scan) body ONCE (measured; DESIGN.md §5). We correct by
    parsing the optimized HLO: per-computation collective operand bytes and
    while-loop trip counts, propagated through the call graph.
  - collective bytes = sum of operand sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, trip-count corrected.
  - FLOPs/bytes corrections use the dominant-scan structure: total ~
    reported + (trip-1) * body share. We cross-check with analytic
    MODEL_FLOPS (6*N*D / 2*N*D) and report both.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (~per chip usable)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """bytes of an HLO shape like 'bf16[16,128,4096]{2,1,0}' or a (possibly
    nested) tuple '(f32[2,4], bf16[8])'."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        b = _DTYPE_BYTES.get(dt)
        if b is None or b == 0:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * b
    return total


@dataclasses.dataclass
class HLOStats:
    collective_bytes: float
    collective_ops: dict
    trip_counts: dict
    flops: float = 0.0          # dot FLOPs, trip-corrected (per device)
    hbm_bytes: float = 0.0      # fusion-boundary operand+output bytes, corrected


# ops that don't move HBM bytes themselves (children or bookkeeping)
_NO_IO = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
          "while", "conditional", "call", "after-all",
          "partition-id", "replica-id"}


_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_HEADER_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_OP_TAIL_RE = re.compile(r"\s*([\w\-]+)\(")


def _split_shape_op(rest: str):
    """Split '<shape> <op>(...' handling arbitrarily nested tuple shapes."""
    if rest.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None, None
        shape, tail = rest[:end + 1], rest[end + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None, None
        shape, tail = rest[:sp], rest[sp:]
    m = _OP_TAIL_RE.match(tail)
    return shape, (m.group(1) if m else None)


def _dot_flops(rest: str, out_shape: str, var_dims: dict, line: str) -> float:
    """FLOPs of a dot: 2 * prod(output dims) * prod(lhs contracting dims)."""
    out_dims = 1
    m = re.search(r"\w+\[([\d,]*)\]", out_shape)
    if m and m.group(1):
        for d in m.group(1).split(","):
            out_dims *= int(d)
    args = re.findall(r"%([\w\.\-]+)", rest.split("(", 1)[1])
    lhs = var_dims.get(args[0]) if args else None
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    contract = 1
    if lhs is not None and cm and cm.group(1):
        for d in cm.group(1).split(","):
            if int(d) < len(lhs):
                contract *= lhs[int(d)]
    return 2.0 * out_dims * contract


def _shape_dims(shape_str: str):
    m = re.search(r"\w+\[([\d,]*)\]", shape_str)
    if not m:
        return ()
    return tuple(int(d) for d in m.group(1).split(",") if d)


def parse_hlo_costs(hlo_text: str) -> HLOStats:
    """Per-computation dot-FLOPs / fusion-boundary HBM bytes / collective
    traffic, propagated through the call graph with while-loop trip counts.

    The optimized HLO is post-fusion SPMD (per-device): each top-level fusion
    or dot reads its operands and writes its output once -> summing operand +
    output bytes across top-level instructions approximates HBM traffic; dot
    FLOPs come from output x contracting dims; collective bytes use output
    shapes (reduce-scatter: its larger operand). `while` bodies multiply by
    backend_config known_trip_count — the correction XLA's own cost_analysis
    (body counted once) lacks.
    """
    comp = defaultdict(lambda: {"coll": 0.0, "flops": 0.0, "bytes": 0.0})
    comp_ops: dict[str, dict] = defaultdict(lambda: defaultdict(float))
    edges: dict[str, list] = defaultdict(list)
    var_bytes: dict[str, int] = {}
    var_dims: dict[str, tuple] = {}
    cur = None

    for line in hlo_text.splitlines():
        header = _HEADER_RE.match(line)
        if header:
            cur = header.group(1)
            var_bytes, var_dims = {}, {}
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        var, rest = mi.group(1), mi.group(2)
        shape_str, op = _split_shape_op(rest)
        if shape_str is None or op is None:
            continue
        nbytes = _shape_bytes(shape_str)
        var_bytes[var] = nbytes
        var_dims[var] = _shape_dims(shape_str)
        base_op = op.replace("-start", "").replace("-done", "")

        if base_op in _COLLECTIVES:
            b = nbytes
            if base_op == "reduce-scatter":
                args = re.findall(r"%([\w\.\-]+)", rest.split("(", 1)[1])
                b = max(b, sum(var_bytes.get(a, 0) for a in args[:1]))
            if op.endswith("-done"):
                continue                      # counted at -start
            comp[cur]["coll"] += b
            comp_ops[cur][base_op] += b
            continue
        if op == "while":
            bm = re.search(r"body=%?([\w\.\-]+)", rest)
            cm2 = re.search(r"condition=%?([\w\.\-]+)", rest)
            t = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', rest)
            trip = int(t.group(1)) if t else 1
            if bm:
                edges[cur].append((bm.group(1), trip, "while"))
            if cm2:
                edges[cur].append((cm2.group(1), trip, "while"))
            continue
        if op in ("call", "conditional"):
            for c in re.findall(r"(?:to_apply|calls|body|branch_\w+|"
                                r"true_computation|false_computation)="
                                r"%?([\w\.\-]+)", rest):
                edges[cur].append((c, 1, "call"))
            continue
        if op == "dot":
            comp[cur]["flops"] += _dot_flops(rest, shape_str, var_dims, line)
        if op == "fusion":
            fm = re.search(r"calls=%?([\w\.\-]+)", rest)
            if fm:
                # fused dots count as FLOPs; fusion-internal ops don't touch HBM
                edges[cur].append((fm.group(1), 1, "fusion"))
        if op not in _NO_IO:
            args = re.findall(r"%([\w\.\-]+)", rest.split("(", 1)[1]) \
                if "(" in rest else []
            io = nbytes + sum(var_bytes.get(a, 0) for a in args)
            comp[cur]["bytes"] += io

    called = {callee for lst in edges.values() for callee, _, _ in lst}
    memo: dict[str, dict] = {}

    def total(c: str, depth=0) -> dict:
        if c in memo:
            return memo[c]
        if depth > 64:
            return {"coll": 0.0, "flops": 0.0, "bytes": 0.0}
        s = dict(comp.get(c, {"coll": 0.0, "flops": 0.0, "bytes": 0.0}))
        for callee, mult, kind in edges.get(c, []):
            sub = total(callee, depth + 1)
            s["coll"] += mult * sub["coll"]
            s["flops"] += mult * sub["flops"]
            if kind != "fusion":        # while/call bodies hold real HBM ops
                s["bytes"] += mult * sub["bytes"]
        memo[c] = s
        return s

    entry = None
    m_entry = re.search(r"^\s*ENTRY\s+%?([\w\.\-]+)", hlo_text, re.M)
    if m_entry:
        entry = m_entry.group(1)
    roots = ([entry] if entry else
             [c for c in set(list(comp) + list(edges)) if c not in called])
    agg = {"coll": 0.0, "flops": 0.0, "bytes": 0.0}
    for r in roots:
        t = total(r)
        for k in agg:
            agg[k] += t[k]
    ops = defaultdict(float)
    for c in comp_ops:
        for op, b in comp_ops[c].items():
            ops[op] += b     # uncorrected per-op breakdown (diagnostic)
    trips = {}
    for lst in edges.values():
        for callee, t, _kind in lst:
            if t > 1:
                trips[callee] = t
    return HLOStats(collective_bytes=agg["coll"], collective_ops=dict(ops),
                    trip_counts=trips, flops=agg["flops"],
                    hbm_bytes=agg["bytes"])


# backwards-compatible alias
parse_hlo_collectives = parse_hlo_costs


def scan_corrected(reported: float, trip_product: int, body_share: float = 0.95):
    """Correct a body-counted-once aggregate: total ~= reported * (share *
    trip + (1-share)). `body_share`: fraction of the reported cost inside the
    scanned body (layer stacks dominate)."""
    return reported * (body_share * trip_product + (1.0 - body_share))


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_hbm: float
    bytes_collective: float
    chips: int
    model_flops: float

    @property
    def compute_s(self):
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self):
        return self.bytes_hbm / (self.chips * HBM_BW)

    @property
    def collective_s(self):
        return self.bytes_collective / (self.chips * ICI_BW)

    @property
    def dominant(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self):
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self):
        return self.model_flops / max(self.flops, 1.0)

    @property
    def roofline_fraction(self):
        """Fraction of the chips' peak the step achieves, assuming perfect
        overlap (model-FLOPs time / bounding-term time)."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / max(self.step_s, 1e-12)

    def as_dict(self):
        return {
            "flops": self.flops, "bytes_hbm": self.bytes_hbm,
            "bytes_collective": self.bytes_collective, "chips": self.chips,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }
