"""Analytic HBM-traffic model per (arch x shape) — the memory roofline term.

HLO-text byte counting overcounts dynamic-slice reads of stacked scan operands
(it sees whole-operand shapes), so the memory term uses this documented
analytic model instead; `xla_cost_analysis_bytes_body_once` is kept in the
dry-run JSON as a diagnostic.

Traffic model (bytes, global, one step; bf16 params/activations, fp32
grad-accum + optimizer moments):

TRAIN, with `mb` gradient-accumulation microbatches:
  per microbatch:
    weights     : 3 reads (fwd, remat re-fwd, bwd)          6*N
    grad accum  : fp32 read+write                           8*N
  once:
    optimizer   : m,v read+write (16*N') + grads read (4*N) + params rw (4*N)
                  N' = N (fp32 moments) or N/2-ish int8
  activations   : kappa_act * T * d_model * 2 per layer (fwd+bwd+remat I/O
                  incl. norms, residuals, projections)
  attention     : flash KV re-reads: 3 * n_attn * B * (S/cq) * ctx * 2*Kv*hd * 2
  lm head       : logits chunks hit HBM: ~6 * T * V * 4
PREFILL: weights 2*N, activations kappa/3, attention KV 1x, last-token logits.
DECODE : weights 2*N_active + full KV-cache read (+1 slot write) + SSM state rw.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeCfg
from repro.models.model import count_params

KAPPA_TRAIN = 45.0      # activation IO passes per layer (fwd+bwd+remat)
KAPPA_FWD = 15.0
CHUNK_Q = 512           # must match models.attention


def _attn_layers(cfg: ModelConfig) -> list[int]:
    """Effective attention context per attention layer instance."""
    ctxs = []
    for mx, _ in cfg.pattern:
        if mx in ("W", "L"):
            ctxs.append(-1)          # window
        elif mx in ("A", "G", "C", "B"):
            ctxs.append(0)           # full
    return ctxs


def memory_bytes(cfg: ModelConfig, shape: ShapeCfg, mb: int = 8,
                 quantized_opt: bool = False) -> float:
    N = count_params(cfg)
    Na = count_params(cfg, active_only=True)
    B, S = shape.global_batch, shape.seq
    V = cfg.padded_vocab
    D = cfg.d_model
    Kv, hd = cfg.attn.n_kv, cfg.attn.head_dim
    L = cfg.n_layers + (cfg.encoder.n_layers if cfg.encoder else 0)

    if shape.kind == "decode":
        total = 2.0 * Na                           # weight reads (bf16)
        n_attn = (cfg.n_super * sum(1 for mx, _ in cfg.pattern
                                    if mx in "AGWLC") + cfg.first_k_dense)
        for mx, _ in cfg.pattern:
            if mx in ("W", "L") and cfg.attn.window:
                ctx = min(cfg.attn.window, S)
            elif mx in ("A", "G", "C"):
                ctx = S
            elif mx == "M":
                d_inner = cfg.ssm.expand * D
                H = d_inner // cfg.ssm.head_dim
                total += cfg.n_super * 2 * (B * H * cfg.ssm.d_state
                                            * cfg.ssm.head_dim * 4.0)
                continue
            else:
                continue
            total += cfg.n_super * B * ctx * 2 * Kv * hd * 2.0   # K+V read
        total += B * V * 4.0                        # logits
        return total

    T = B * S
    if cfg.encoder is not None:
        T = B * cfg.encoder.dec_seq
        T_enc = B * S
    else:
        T_enc = 0

    # attention KV re-read traffic (flash: K,V streamed per q-chunk)
    def kv_traffic(tokens, seq, passes):
        tr = 0.0
        for mx, _ in cfg.pattern:
            if mx in ("W", "L") and cfg.attn.window:
                ctx = min(cfg.attn.window + CHUNK_Q, seq)
            elif mx in ("A", "G", "C"):
                ctx = seq
            else:
                continue
            nq = max(seq // CHUNK_Q, 1)
            tr += cfg.n_super * (tokens / seq) * nq * ctx * 2 * Kv * hd * 2.0
        return tr * passes

    if shape.kind == "train":
        total = mb * (6.0 * N + 8.0 * N)
        opt_moment = 2.0 * N if quantized_opt else 8.0 * N
        total += 2 * opt_moment + 4.0 * N + 4.0 * N
        total += KAPPA_TRAIN * (T + T_enc) * D * 2.0 * L
        total += kv_traffic(T, min(S, 10**9), passes=3.0)
        total += 6.0 * T * V * 4.0
        return total

    # prefill
    total = 2.0 * N
    total += KAPPA_FWD * (T + T_enc) * D * 2.0 * L
    total += kv_traffic(T, S, passes=1.0)
    total += B * V * 4.0
    return total
