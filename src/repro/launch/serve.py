"""Batched serving driver: continuous-batching greedy decode.

    python -m repro.launch.serve --arch mamba2-370m --smoke --requests 4
"""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import build_model
    from repro.train.serve_step import BatchServer, Request

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    server = BatchServer(model, params, batch=args.batch,
                         max_seq=args.max_seq)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab, 8).tolist(),
                    max_new=args.max_new) for _ in range(args.requests)]
    pending = list(reqs)
    done = []
    steps = 0
    while pending or any(server.slots):
        while pending and server.admit(pending[0]):
            pending.pop(0)
        server.step()
        steps += 1
        done = [r for r in reqs if r.done]
        if steps > 10000:
            break
    for i, r in enumerate(reqs):
        print(f"req{i}: prompt={r.prompt[:4]}... -> {r.generated}")
    print(f"[serve] {len(done)}/{len(reqs)} completed in {steps} decode steps")


if __name__ == "__main__":
    main()
