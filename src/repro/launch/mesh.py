"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data x model).
    Multi-pod: 2 x 16 x 16 = 512 chips (pod x data x model); the 'pod' axis
    joins data parallelism (DCN-connected, gradient all-reduce only)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the locally visible devices (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
