import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes — 16x16 (single pod, 256 chips) and 2x16x16 (2 pods,
512 chips) — and extract memory / cost / collective analyses for §Roofline.

The XLA_FLAGS line above MUST run before any jax import: jax locks the device
count at first init. Never set this flag globally (tests/benches want 1 CPU).

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all --out results/dryrun.json
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import Roofline, parse_hlo_collectives
from repro.models import build_model
from repro.models.model import model_flops
from repro.sharding import policies
from repro.train.optimizer import adamw, quantized_adamw
from repro.train.serve_step import make_serve_step
from repro.train.train_step import make_train_step

# Training memory knobs per arch (microbatching + int8 moments for the 398B).
TRAIN_MICROBATCH = {"default": 8, "jamba-1.5-large-398b": 16}
QUANTIZED_OPT = {"jamba-1.5-large-398b", "mixtral-8x22b"}
# Baseline uses full remat for training (save only super-block boundaries);
# block-level dot-saving is a §Perf iteration (memory <-> recompute tradeoff).
TRAIN_REMAT = "full"


def apply_variant(cfg, variant: str, mesh):
    """§Perf beyond-baseline optimizations, applied per variant tag."""
    import dataclasses as _dc
    if variant == "baseline":
        return cfg
    if cfg.moe is not None and "moe_local" in variant:
        data = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, dispatch_groups=data))
    if cfg.moe is not None and "moe_tp" in variant:
        data = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, dispatch_groups=data,
                                               prefer_tp=True))
    if "remat_block" in variant:
        cfg = _dc.replace(cfg, remat="block")
    if "remat_none" in variant:
        cfg = _dc.replace(cfg, remat="none")
    if "seqpar" in variant:
        cfg = _dc.replace(cfg, seq_shard=True)
    if "savear" in variant:
        cfg = _dc.replace(cfg, remat="collectives")
    return cfg


def lower_cell(arch: str, shape_name: str, mesh, multi_pod: bool,
               variant: str = "baseline"):
    import dataclasses as _dc
    cfg = get_config(arch)
    if SHAPES[shape_name].kind == "train":
        cfg = _dc.replace(cfg, remat=TRAIN_REMAT)
    cfg = apply_variant(cfg, variant, mesh)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": reason}

    model = build_model(cfg)
    t0 = time.time()
    from repro.models.model import abstract_init
    from repro.sharding.context import sharding_ctx
    param_shapes, roles = abstract_init(model)
    pspecs = policies.param_specs(roles, param_shapes, cfg, mesh)
    batch_sds = model.input_specs(shape)
    bspecs = policies.batch_specs(cfg, shape, mesh, batch_sds)
    pol = policies.resolve_policy(cfg, mesh)
    ctx = sharding_ctx(mesh, pol)
    ctx.__enter__()

    if shape.kind == "train":
        quant = arch in QUANTIZED_OPT
        opt = (quantized_adamw if quant else adamw)(1e-4, weight_decay=0.1)
        opt_shapes = jax.eval_shape(opt.init, param_shapes)
        ospecs = policies.opt_state_specs(pspecs, param_shapes, mesh, cfg,
                                          quantized=quant)
        mb = TRAIN_MICROBATCH.get(arch, TRAIN_MICROBATCH["default"])
        if "mb16" in variant:
            mb = 16
        if "mb32" in variant:
            mb = 32
        gspecs = policies.zero_shard_specs(pspecs, param_shapes, mesh, cfg)
        step_fn = make_train_step(model, opt, microbatches=mb,
                                  grad_shardings=gspecs,
                                  batch_shardings=bspecs)
        jf = jax.jit(step_fn, in_shardings=(pspecs, ospecs, bspecs, None),
                     out_shardings=(pspecs, ospecs, None))
        lowered = jf.lower(param_shapes, opt_shapes, batch_sds,
                           jax.ShapeDtypeStruct((), jnp.int32))
        trip_extra = mb
    elif shape.kind == "prefill":
        def prefill_step(params, batch):
            hidden, _ = model.apply(params, batch)
            return model.logits(params, hidden[:, -1:])

        jf = jax.jit(prefill_step, in_shardings=(pspecs, bspecs))
        lowered = jf.lower(param_shapes, batch_sds)
        trip_extra = 1
    else:  # decode
        serve = make_serve_step(model)
        # donate the KV/SSM caches: in-place update aliasing halves decode
        # residency (without it the old+new cache coexist, §Perf D1)
        jf = jax.jit(serve, in_shardings=(
            pspecs, bspecs["token"], bspecs["caches"], bspecs["position"]),
            donate_argnums=(2,))
        lowered = jf.lower(param_shapes, batch_sds["token"],
                           batch_sds["caches"], batch_sds["position"])
        trip_extra = 1

    ctx.__exit__(None, None, None)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # per-device costs from the partitioned HLO, while-trip corrected
    stats = parse_hlo_collectives(hlo)

    chips = policies.count_devices(mesh)
    flops_dev_raw = float(ca.get("flops", 0.0))       # body-once (diagnostic)
    bytes_dev_raw = float(ca.get("bytes accessed", 0.0))
    mf = model_flops(cfg, shape)
    from repro.launch.memory_model import memory_bytes
    mem_bytes = memory_bytes(cfg, shape,
                             mb=trip_extra if shape.kind == "train" else 1,
                             quantized_opt=arch in QUANTIZED_OPT)

    roof = Roofline(flops=stats.flops * chips,
                    bytes_hbm=mem_bytes,
                    bytes_collective=stats.collective_bytes * chips,
                    chips=chips, model_flops=mf)
    per_dev_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     + mem.temp_size_in_bytes + mem.generated_code_size_in_bytes
                     - mem.alias_size_in_bytes)   # donated buffers counted once
    return {
        "status": "ok",
        "chips": chips,
        "multi_pod": multi_pod,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_device": int(mem.argument_size_in_bytes),
            "output_bytes_per_device": int(mem.output_size_in_bytes),
            "temp_bytes_per_device": int(mem.temp_size_in_bytes),
            "total_bytes_per_device": int(per_dev_bytes),
            "fits_16GB": bool(per_dev_bytes < 16e9),
        },
        "xla_cost_analysis_flops_body_once": flops_dev_raw,
        "xla_cost_analysis_bytes_body_once": bytes_dev_raw,
        "hlo_parsed_hbm_bytes_per_device": stats.hbm_bytes,
        "collective_ops_bytes_raw": {k: float(v) for k, v in
                                     stats.collective_ops.items()},
        "trip_counts": stats.trip_counts,
        "roofline": roof.as_dict(),
    }


def run_cell(arch, shape_name, multi_pod, out, variant="baseline"):
    key = f"{arch}|{shape_name}|{'multi' if multi_pod else 'single'}"
    if variant != "baseline":
        key += f"|{variant}"
    mesh = make_production_mesh(multi_pod=multi_pod)
    print(f"=== {key} ===", flush=True)
    try:
        res = lower_cell(arch, shape_name, mesh, multi_pod, variant)
    except Exception as e:
        traceback.print_exc()
        res = {"status": "error", "error": f"{type(e).__name__}: {e}"}
    out[key] = res
    if res["status"] == "ok":
        r = res["roofline"]
        print(f"  compile={res['compile_s']}s "
              f"mem/dev={res['memory']['total_bytes_per_device']/1e9:.2f}GB "
              f"compute={r['compute_s']*1e3:.2f}ms memory={r['memory_s']*1e3:.2f}ms "
              f"coll={r['collective_s']*1e3:.2f}ms dom={r['dominant']} "
              f"roofline_frac={r['roofline_fraction']:.3f}", flush=True)
    else:
        print(f"  {res['status']}: {res.get('reason', res.get('error'))}",
              flush=True)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    out = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            out = json.load(f)

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape, False))
                cells.append((arch, shape, True))
    else:
        meshes = [args.multi_pod] if not args.both_meshes else [False, True]
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    for arch, shape, mp in cells:
        key = f"{arch}|{shape}|{'multi' if mp else 'single'}"
        if args.variant != "baseline":
            key += f"|{args.variant}"
        if out.get(key, {}).get("status") == "ok":
            print(f"=== {key} === (cached)", flush=True)
            continue
        run_cell(arch, shape, mp, out, args.variant)
        with open(args.out, "w") as f:
            json.dump(out, f, indent=1)

    n_ok = sum(1 for v in out.values() if v["status"] == "ok")
    n_skip = sum(1 for v in out.values() if v["status"] == "skipped")
    n_err = sum(1 for v in out.values() if v["status"] == "error")
    print(f"\nDONE: {n_ok} ok, {n_skip} skipped, {n_err} errors -> {args.out}")


if __name__ == "__main__":
    main()
