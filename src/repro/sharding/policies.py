"""Sharding policies: semantic axis roles -> PartitionSpecs.

Model init returns a `roles` pytree mirroring params, each leaf a tuple of
axis-role names (see models.layers docstring). The policy maps roles onto the
mesh, driven by divisibility (JAX rejects uneven argument shardings):

  - Megatron TP on 'model': vocab, ff, merged q/kv head dims, MoE expert_ff or
    expert axis (EP when n_routed % model == 0), mamba inner dims.
  - FSDP fallback: when a role cannot shard (e.g. 40 heads on a 16-way axis is
    irrelevant — merged dims still shard; only *activation* head sharding
    changes), weights remain sharded and XLA gathers them per layer.
  - ZeRO: optimizer moments take the param spec plus the data axis on the
    largest remaining divisible dim.
  - Decode caches: sequence-sharded over 'model' (flash-decoding SP);
    long_500k (batch=1) shards sequence over every mesh axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCfg

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Policy:
    model_axis: str = "model"
    data_axes: tuple = ("data",)
    moe_ep: bool = True
    attn_tp: bool = True          # informational (activation-level choice)
    zero_opt: bool = True
    fsdp_params: bool = False     # shard params over data too (ZeRO-3 style)

    @property
    def dp(self):
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]


# Param bytes per chip above which TP-only param residency can't fit and
# the policy adds data-axis (FSDP) param sharding.
FSDP_THRESHOLD_BYTES = 8e9


def resolve_policy(cfg: ModelConfig, mesh: Mesh) -> Policy:
    from repro.models.model import count_params
    model_size = mesh.shape["model"]
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    moe_ep = bool(cfg.moe and cfg.moe.n_routed % model_size == 0
                  and not cfg.moe.prefer_tp)
    attn_tp = cfg.attn.n_heads % model_size == 0
    fsdp = count_params(cfg) * 2 / model_size > FSDP_THRESHOLD_BYTES
    return Policy(data_axes=data_axes, moe_ep=moe_ep, attn_tp=attn_tp,
                  fsdp_params=fsdp)


def _role_axis(role: str | None, pol: Policy, cfg: ModelConfig, dim: int,
               model_size: int):
    if role is None:
        return None
    table = {
        "vocab": "model",
        "ff": "model",
        "qheads": "model",
        "kvheads": "model",
        "inner": "model",
        "inner_proj": "model",
        "conv_ch": "model",
        "expert_ff": None if pol.moe_ep else "model",
        "experts": "model" if pol.moe_ep else None,
        "embed": None,
        "heads": None,
        "layers": None,
    }
    axis = table.get(role)
    if axis == "model" and dim % model_size != 0:
        return None                      # divisibility guard
    return axis


def param_specs(roles: PyTree, shapes: PyTree, cfg: ModelConfig,
                mesh: Mesh) -> PyTree:
    """PartitionSpec per param leaf from its role tuple + shape."""
    pol = resolve_policy(cfg, mesh)
    model_size = mesh.shape["model"]

    data_size = int(np.prod([mesh.shape[a] for a in pol.data_axes]))

    def one(role_tuple, shp):
        dims = shp.shape
        spec = []
        used_model = False
        for role, d in zip(role_tuple, dims):
            ax = _role_axis(role, pol, cfg, d, model_size)
            if ax == "model" and used_model:
                ax = None                # one model axis per tensor
            if ax == "model":
                used_model = True
            spec.append(ax)
        if pol.fsdp_params:
            # ZeRO-3: additionally shard the largest remaining dim over data
            cands = [(d, i) for i, (d, s) in enumerate(zip(dims, spec))
                     if s is None and d % data_size == 0 and d >= data_size]
            if cands:
                _, idx = max(cands)
                spec[idx] = pol.dp
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, roles, shapes,
                        is_leaf=lambda t: isinstance(t, tuple) and all(
                            x is None or isinstance(x, str) for x in t))


def zero_shard_specs(specs: PyTree, shapes: PyTree, mesh: Mesh,
                     cfg: ModelConfig) -> PyTree:
    """Optimizer-state shardings: param spec + data axis on the largest
    remaining divisible dim (ZeRO-1 partitioning of moments)."""
    pol = resolve_policy(cfg, mesh)
    data_size = int(np.prod([mesh.shape[a] for a in pol.data_axes]))

    def one(sharding, shp):
        spec = list(sharding.spec) + [None] * (len(shp.shape)
                                               - len(sharding.spec))
        if any(s is not None and ("data" in (s if isinstance(s, tuple)
                                             else (s,))) for s in spec):
            return NamedSharding(mesh, P(*spec))    # already data-sharded
        cands = [(d, i) for i, (d, s) in enumerate(zip(shp.shape, spec))
                 if s is None and d % data_size == 0 and d >= data_size]
        if cands:
            _, idx = max(cands)
            spec[idx] = pol.data_axes if len(pol.data_axes) > 1 else \
                pol.data_axes[0]
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, specs, shapes)


def opt_state_specs(param_sharding: PyTree, param_shapes: PyTree, mesh: Mesh,
                    cfg: ModelConfig, quantized: bool = False) -> PyTree:
    """Shardings for the optimizer state pytree.

    Plain: {'m','v'} fp32, ZeRO-sharded (param spec + data axis).
    Quantized: {'mq','ms','vq','vs'} — payload (..., F/256, 256) inherits the
    param's sharding with the last-dim axis moved to the F/256 dim; leaves
    whose last dim doesn't divide 256 fall back to fp32 {'m','v'}.
    """
    from repro.train.optimizer import quantizable
    z = zero_shard_specs(param_sharding, param_shapes, mesh, cfg)
    if not quantized:
        return {"m": z, "v": z}
    model_size = mesh.shape["model"]

    def one(sharding, zspec, shp):
        if not quantizable(shp.shape):
            return {"m": zspec, "v": zspec}
        spec = list(sharding.spec) + [None] * (len(shp.shape)
                                               - len(sharding.spec))
        last = spec[-1]
        nb = shp.shape[-1] // 256
        axis_sz = {None: 1}
        last_ok = last is None or nb % int(np.prod(
            [mesh.shape[a] for a in (last if isinstance(last, tuple)
                                     else (last,))])) == 0
        qspec = NamedSharding(mesh, P(*spec[:-1],
                                      last if last_ok else None, None))
        sspec = NamedSharding(mesh, P(*spec[:-1], last if last_ok else None))
        return {"mq": qspec, "ms": sspec, "vq": qspec, "v_lo": sspec,
                "v_sc": sspec}

    return jax.tree.map(one, param_sharding, z, param_shapes)


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapeCfg, mesh: Mesh,
                specs_tree: PyTree) -> PyTree:
    """Shardings matching model.input_specs(shape)."""
    pol = resolve_policy(cfg, mesh)
    dp = pol.dp
    B = shape.global_batch
    dp_size = int(np.prod([mesh.shape[a] for a in pol.data_axes]))
    bspec = dp if B % dp_size == 0 else None

    def spec_for(path_key: str, sds):
        nd = len(sds.shape)
        if path_key in ("tokens", "labels", "token"):
            return NamedSharding(mesh, P(*([bspec] + [None] * (nd - 1))))
        if path_key in ("enc_frames", "img_embed"):
            return NamedSharding(mesh, P(*([bspec] + [None] * (nd - 1))))
        if path_key == "position":
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P())

    out = {}
    for k, v in specs_tree.items():
        if k == "caches":
            out[k] = cache_specs(cfg, shape, mesh, v)
        else:
            out[k] = spec_for(k, v)
    return out


def cache_specs(cfg: ModelConfig, shape: ShapeCfg, mesh: Mesh,
                caches: PyTree) -> PyTree:
    """Decode-cache shardings.

    Attention k/v (n_super, B, S, K, hd): sequence over 'model'
    (flash-decoding); batch over data axes. With batch=1 (long_500k) the
    sequence takes every axis. Mamba ssm (n_super, B, H, N, P): heads over
    'model'. Conv (n_super, B, K-1, CH): channels over 'model'.
    """
    pol = resolve_policy(cfg, mesh)
    model_size = mesh.shape["model"]
    dp_size = int(np.prod([mesh.shape[a] for a in pol.data_axes]))
    all_axes = pol.data_axes + ("model",)
    all_size = dp_size * model_size

    def one_leaf(path, sds):
        dims = sds.shape
        nd = len(dims)
        name = str(getattr(path[-1], "key", ""))
        if name in ("ssm",):
            lead = nd - 4
            B, H = dims[lead], dims[lead + 1]
            b = pol.dp if B % dp_size == 0 and B > 1 else None
            h = "model" if H % model_size == 0 else None
            return NamedSharding(mesh, P(*([None] * lead + [b, h, None, None])))
        if name in ("conv",):
            lead = nd - 3
            B, CH = dims[lead], dims[lead + 2]
            b = pol.dp if B % dp_size == 0 and B > 1 else None
            c = "model" if CH % model_size == 0 else None
            return NamedSharding(mesh, P(*([None] * lead + [b, None, c])))
        # attention caches k/v/xk/xv: (..., B, S, K, hd)
        lead = nd - 4
        B, S = dims[lead], dims[lead + 1]
        if B % dp_size == 0 and B > 1:
            b = pol.dp
            s = "model" if S % model_size == 0 else None
        else:
            b = None
            s = all_axes if S % all_size == 0 else (
                "model" if S % model_size == 0 else None)
        return NamedSharding(mesh, P(*([None] * lead + [b, s, None, None])))

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    return jax.tree_util.tree_unflatten(
        treedef, [one_leaf(p, l) for p, l in flat])


def count_devices(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
