"""Activation-sharding context: models stay mesh-agnostic, launchers install
a policy that turns logical axis tags into with_sharding_constraint calls.

    with sharding_ctx(mesh, policy):
        ...  # model code calls constrain(x, ("data", None, "model"))

Outside a context (unit tests, single-device runs) `constrain` is identity.
Logical axes: 'data' -> the policy's data axes (('pod','data') on multi-pod),
'model' -> the model axis. Dims that don't divide their mesh axes are left
unconstrained (JAX rejects uneven shardings).
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

_STATE: dict = {"mesh": None, "dp": None}


@contextlib.contextmanager
def sharding_ctx(mesh, policy):
    old = dict(_STATE)
    _STATE["mesh"] = mesh
    _STATE["dp"] = policy.dp
    try:
        yield
    finally:
        _STATE.update(old)


def _axis_size(mesh, ax):
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        return int(np.prod([mesh.shape[a] for a in ax]))
    return mesh.shape[ax]


def constrain(x, axes: tuple):
    """axes: logical tags per dim ('data' | 'model' | None)."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return x
    spec = []
    for d, tag in zip(x.shape, axes):
        ax = _STATE["dp"] if tag == "data" else ("model" if tag == "model"
                                                 else None)
        if ax is not None and d % _axis_size(mesh, ax) != 0:
            ax = None
        spec.append(ax)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
