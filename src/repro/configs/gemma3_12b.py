"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.

5:1 local:global attention interleave (window 1024), 128k context.
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, d_ff=15360, vocab=262144,
    attn=AttnCfg(n_heads=16, n_kv=8, head_dim=256, window=1024,
                 rope_theta=1_000_000.0),
    pattern=(("L", "D"),) * 5 + (("G", "D"),),
    tie_embeddings=True,
    long_context_ok=True,   # 5/6 of layers are local (linear); global layers decode O(S)
    source="[hf:google/gemma-3-1b-pt; unverified]",
)

SMOKE = ModelConfig(
    name="gemma3-12b-smoke", family="dense",
    n_layers=6, d_model=64, d_ff=128, vocab=512,
    attn=AttnCfg(n_heads=4, n_kv=2, head_dim=16, window=32),
    pattern=(("L", "D"),) * 5 + (("G", "D"),),
    tie_embeddings=True, long_context_ok=True, vocab_pad_to=16,
)
