"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
MoE 8 experts top-2, sliding-window attention. [arXiv:2401.04088; hf]

8 experts < model axis (16): experts run TP-in-expert (d_ff sharded), no EP.
"""
from repro.configs.base import AttnCfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, d_ff=16384, vocab=32768,
    attn=AttnCfg(n_heads=48, n_kv=8, head_dim=128, window=4096),
    pattern=(("W", "E"),),
    moe=MoECfg(n_routed=8, top_k=2, d_expert=16384),
    long_context_ok=True,   # SWA: decode cache = sliding window
    source="[arXiv:2401.04088; hf]",
)

SMOKE = ModelConfig(
    name="mixtral-smoke", family="moe",
    n_layers=2, d_model=64, d_ff=128, vocab=512,
    attn=AttnCfg(n_heads=4, n_kv=2, head_dim=16, window=32),
    pattern=(("W", "E"),),
    moe=MoECfg(n_routed=4, top_k=2, d_expert=128),
    long_context_ok=True, vocab_pad_to=16,
)
