"""The paper's own system configuration (Table 1) as named presets."""
from repro.nmp.config import NMPConfig

# 4x4 memory-cube mesh, 4 MCs, 512-entry NMP tables, 256-entry page cache
PAPER_4X4 = NMPConfig()

# §7.5.1 scalability study
PAPER_8X8 = NMPConfig(mesh_x=8, mesh_y=8)

# §7.6 sensitivity sweep points
PAGE_CACHE_SWEEP = (32, 64, 128, 256)
NMP_TABLE_SWEEP = (32, 64, 128, 512)
