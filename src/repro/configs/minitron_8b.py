"""minitron-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.

Pruned nemotron. [arXiv:2407.14679; hf]
"""
from repro.configs.base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, d_ff=16384, vocab=256000,
    attn=AttnCfg(n_heads=32, n_kv=8, head_dim=128),
    pattern=(("A", "D"),),
    source="[arXiv:2407.14679; hf]",
)

SMOKE = ModelConfig(
    name="minitron-8b-smoke", family="dense",
    n_layers=2, d_model=64, d_ff=128, vocab=512,
    attn=AttnCfg(n_heads=4, n_kv=2, head_dim=16),
    pattern=(("A", "D"),), vocab_pad_to=16,
)
