"""mamba2-370m [ssm]: 48L d_model=1024, attention-free, d_ff=0, vocab=50280,
ssm_state=128 — SSD (state-space duality). [arXiv:2405.21060; unverified]

d_inner = 2*d_model = 2048, head_dim 64 => 32 SSD heads. No FFN blocks
(listed d_ff=0): each layer is a single Mamba2 mixer.
"""
from repro.configs.base import AttnCfg, ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, d_ff=0, vocab=50280,
    attn=AttnCfg(n_heads=16, n_kv=16, head_dim=64),   # unused (attention-free)
    pattern=(("M", "N"),),
    ssm=SSMCfg(d_state=128, head_dim=64, expand=2, chunk=256),
    tie_embeddings=True,
    long_context_ok=True,
    source="[arXiv:2405.21060; unverified]",
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=64, d_ff=0, vocab=512,
    attn=AttnCfg(n_heads=4, n_kv=4, head_dim=16),
    pattern=(("M", "N"),),
    ssm=SSMCfg(d_state=16, head_dim=16, expand=2, chunk=32),
    tie_embeddings=True, long_context_ok=True, vocab_pad_to=16,
)
