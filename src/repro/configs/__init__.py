"""Architecture registry: the 10 assigned architectures + the paper's own
NMP system config. Select with --arch <id>."""
from repro.configs.base import (SHAPES, SMOKE_SHAPE, AttnCfg, EncoderCfg,
                                ModelConfig, MoECfg, ShapeCfg, SSMCfg,
                                shape_applicable)

from repro.configs import (deepseek_moe_16b, gemma3_12b, jamba_1_5_large_398b,
                           llama_3_2_vision_11b, mamba2_370m, minitron_8b,
                           mixtral_8x22b, phi3_medium_14b, qwen3_32b,
                           whisper_large_v3)

_MODULES = {
    "gemma3-12b": gemma3_12b,
    "minitron-8b": minitron_8b,
    "phi3-medium-14b": phi3_medium_14b,
    "qwen3-32b": qwen3_32b,
    "jamba-1.5-large-398b": jamba_1_5_large_398b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "mixtral-8x22b": mixtral_8x22b,
    "whisper-large-v3": whisper_large_v3,
    "llama-3.2-vision-11b": llama_3_2_vision_11b,
    "mamba2-370m": mamba2_370m,
}

ARCHS = tuple(_MODULES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod = _MODULES[name]
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False):
    return {n: get_config(n, smoke) for n in ARCHS}
