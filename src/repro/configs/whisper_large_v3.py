"""whisper-large-v3 [audio]: enc-dec, 32L d_model=1280 20H (kv=20) d_ff=5120
vocab=51866. [arXiv:2212.04356; unverified]

The conv audio frontend is a STUB per the brief: input_specs() provides
precomputed frame embeddings (B, S_enc, d_model). GELU MLPs (no SwiGLU).
20 heads do not divide the model axis: FSDP-fallback attention policy.
vocab padded 51866 -> 51968 (Megatron-style) for TP divisibility.
"""
from repro.configs.base import AttnCfg, EncoderCfg, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, d_model=1280, d_ff=5120, vocab=51866,
    attn=AttnCfg(n_heads=20, n_kv=20, head_dim=64),
    pattern=(("C", "D"),),            # decoder: self + cross each layer
    encoder=EncoderCfg(n_layers=32, dec_seq=448),
    swiglu=False,
    source="[arXiv:2212.04356; unverified]",
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, d_model=64, d_ff=128, vocab=512,
    attn=AttnCfg(n_heads=4, n_kv=4, head_dim=16),
    pattern=(("C", "D"),),
    encoder=EncoderCfg(n_layers=2, dec_seq=16),
    swiglu=False, vocab_pad_to=16,
)
