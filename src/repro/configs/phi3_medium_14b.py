"""phi3-medium-14b [dense]: 40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352.

RoPE SwiGLU GQA. [arXiv:2404.14219; unverified]
40 heads / 10 kv heads do not divide the model axis (16): attention runs under
the FSDP fallback policy (weights sharded+gathered; MLP stays Megatron-TP).
"""
from repro.configs.base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, d_ff=17920, vocab=100352,
    attn=AttnCfg(n_heads=40, n_kv=10, head_dim=128),
    pattern=(("A", "D"),),
    source="[arXiv:2404.14219; unverified]",
)

SMOKE = ModelConfig(
    name="phi3-medium-14b-smoke", family="dense",
    n_layers=2, d_model=80, d_ff=160, vocab=512,
    attn=AttnCfg(n_heads=5, n_kv=5, head_dim=16),
    pattern=(("A", "D"),), vocab_pad_to=16,
)
