"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2, Mamba+attention 1:7 interleave.

[arXiv:2403.19887; hf]. Mamba blocks use the Mamba2/SSD formulation (DESIGN.md
hardware-adaptation note); MoE on every other layer, attention at position 3
of each 8-layer super-block.
"""
from repro.configs.base import AttnCfg, ModelConfig, MoECfg, SSMCfg

_PATTERN = (
    ("M", "D"), ("M", "E"), ("M", "D"), ("A", "E"),
    ("M", "D"), ("M", "E"), ("M", "D"), ("M", "E"),
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, d_ff=24576, vocab=65536,
    attn=AttnCfg(n_heads=64, n_kv=8, head_dim=128),
    pattern=_PATTERN,
    moe=MoECfg(n_routed=16, top_k=2, d_expert=24576),
    ssm=SSMCfg(d_state=128, head_dim=64, expand=2, chunk=128),
    long_context_ok=True,
    source="[arXiv:2403.19887; hf]",
)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid",
    n_layers=8, d_model=64, d_ff=128, vocab=512,
    attn=AttnCfg(n_heads=4, n_kv=2, head_dim=16),
    pattern=_PATTERN,
    moe=MoECfg(n_routed=4, top_k=2, d_expert=128),
    ssm=SSMCfg(d_state=16, head_dim=16, expand=2, chunk=32),
    long_context_ok=True, vocab_pad_to=16,
)
