"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64 routed top-6 + 2 shared, fine-grained experts,
first layer dense. [arXiv:2401.06066; hf]
"""
from repro.configs.base import AttnCfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, d_ff=11264,     # dense first-layer FFN (8x expert)
    vocab=102400,
    attn=AttnCfg(n_heads=16, n_kv=16, head_dim=128),
    pattern=(("A", "E"),),
    first_k_dense=1,
    moe=MoECfg(n_routed=64, top_k=6, d_expert=1408, n_shared=2,
               router_pre_softmax=True),
    source="[arXiv:2401.06066; hf]",
)

SMOKE = ModelConfig(
    name="deepseek-moe-smoke", family="moe",
    n_layers=3, d_model=64, d_ff=256, vocab=512,
    attn=AttnCfg(n_heads=4, n_kv=4, head_dim=16),
    pattern=(("A", "E"),), first_k_dense=1,
    moe=MoECfg(n_routed=8, top_k=2, d_expert=32, n_shared=2,
               router_pre_softmax=True),
    vocab_pad_to=16,
)
