"""Architecture config system.

Every assigned architecture is a `ModelConfig` built from composable parts:
GQA attention (full / sliding-window / local:global), SwiGLU or MoE FFNs,
Mamba2-SSD mixers (pure or hybrid interleave), optional encoder stack
(enc-dec) and cross-attention layers (VLM).

Layers are grouped into a repeating *super-block* `pattern` (a tuple of
(mixer, ffn) kind pairs); the transformer scans over `n_layers/len(pattern)`
super-blocks so the lowered HLO stays compact at any depth.

Mixer kinds: 'A' causal full attention | 'W' sliding-window attention |
             'L' local attention (window) | 'G' global full attention |
             'M' Mamba2 SSD | 'C' cross-attention (+causal self) |
             'B' bidirectional attention (encoder)
FFN kinds:   'D' dense SwiGLU | 'E' mixture-of-experts
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class AttnCfg:
    n_heads: int
    n_kv: int
    head_dim: int
    qk_norm: bool = False
    window: int = 4096          # used by 'W' (SWA) and 'L' (local) mixers
    rope_theta: float = 1e4
    softmax_scale: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_routed: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_pre_softmax: bool = False   # deepseek-style: softmax over all, then top-k
    dispatch_groups: int = 1           # shard-local dispatch: set to the data-
                                       # parallel degree so routing/capacity are
                                       # computed per data shard (no global
                                       # gather of the dispatch buffers)
    prefer_tp: bool = False            # force TP-in-expert even when the expert
                                       # count divides the model axis (fine-
                                       # grained experts: no token exchange)


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256
    conv: int = 4
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class EncoderCfg:
    """Auxiliary encoder stack (whisper). The modality frontend is a stub:
    input_specs() supplies precomputed frame embeddings (B, S_enc, d_model)."""
    n_layers: int = 32
    seq_frac: float = 1.0       # encoder seq = seq_frac * shape.seq
    dec_seq: int = 448          # decoder text length for train/prefill shapes


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    attn: AttnCfg
    pattern: tuple = (("A", "D"),)
    first_k_dense: int = 0      # leading layers forced to dense FFN (deepseek)
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    encoder: Optional[EncoderCfg] = None
    n_img_tokens: int = 0       # VLM stub: precomputed patch embeddings
    norm_eps: float = 1e-6
    vocab_pad_to: int = 128
    tie_embeddings: bool = False
    swiglu: bool = True         # False => GELU MLP (whisper)
    seq_shard: bool = False     # sequence-parallel residual stream: hidden is
                                # (data, model)-sharded between blocks, turning
                                # TP all-reduces into reduce-scatter/all-gather
                                # pairs at half the wire bytes (§Perf B1)
    source: str = ""            # provenance note [source; verified-tier]
    long_context_ok: bool = False  # sub-quadratic: eligible for long_500k
    skip_decode: bool = False      # encoder-only archs
    remat: str = "block"        # none | block | full

    @property
    def padded_vocab(self) -> int:
        pad = self.vocab_pad_to
        return (self.vocab + pad - 1) // pad * pad

    @property
    def n_super(self) -> int:
        n = self.n_layers - self.first_k_dense
        assert n % len(self.pattern) == 0, (self.name, n, len(self.pattern))
        return n // len(self.pattern)

    def param_count(self) -> int:
        """Total parameter count N (for MODEL_FLOPS = 6*N*D)."""
        from repro.models.model import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params
        return count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq: int
    global_batch: int
    kind: str       # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}

# smoke-test shapes (reduced)
SMOKE_SHAPE = ShapeCfg("smoke", 128, 2, "train")


def shape_applicable(cfg: ModelConfig, shape: ShapeCfg) -> tuple[bool, str]:
    """Whether a (arch, shape) cell runs; reason recorded when skipped."""
    if shape.name == "long_500k" and not cfg.long_context_ok:
        return False, "pure full attention: 500k decode needs sub-quadratic attention"
    if shape.kind == "decode" and cfg.skip_decode:
        return False, "encoder-only: no decode step"
    return True, ""
