"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256, cross-attention image layers (every 5th layer).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision tower is a STUB per the brief: input_specs() provides precomputed
patch embeddings (B, 1601, d_model) already projected to the text width.
"""
from repro.configs.base import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, d_ff=14336, vocab=128256,
    attn=AttnCfg(n_heads=32, n_kv=8, head_dim=128, rope_theta=5e5),
    pattern=(("C", "D"),) + (("A", "D"),) * 4,   # 8 cross + 32 self layers
    n_img_tokens=1601,
    source="[hf:meta-llama/Llama-3.2-11B-Vision; unverified]",
)

SMOKE = ModelConfig(
    name="llama-vision-smoke", family="vlm",
    n_layers=5, d_model=64, d_ff=128, vocab=512,
    attn=AttnCfg(n_heads=4, n_kv=2, head_dim=16),
    pattern=(("C", "D"),) + (("A", "D"),) * 4,
    n_img_tokens=17, vocab_pad_to=16,
)
