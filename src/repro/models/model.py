"""Unified model API.

build_model(cfg) -> Model with:
  init(rng)                          -> (params, roles)
  apply(params, batch)               -> (hidden (B,S,D), aux)    [train/prefill]
  logits(params, hidden_chunk)       -> (.., V_padded)           [chunked head]
  decode_step(params, token, caches, position) -> (logits, caches)
  init_caches(batch, seq)            -> cache pytree
  input_specs(shape)                 -> (batch dict of ShapeDtypeStruct)
  count_params / flops helpers

Batch layout (synthetic pipeline produces exactly this):
  tokens (B, S) i32, plus per-family extras:
    encdec : enc_frames (B, S_enc, D) stub frame embeddings
    vlm    : img_embed (B, n_img, D) stub patch embeddings
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCfg
from repro.models import layers, mamba, transformer
from repro.models.layers import DTYPE


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable
    apply: Callable
    logits: Callable
    decode_step: Callable
    init_caches: Callable
    input_specs: Callable


def build_model(cfg: ModelConfig) -> Model:
    V = cfg.padded_vocab

    def init(rng):
        keys = jax.random.split(rng, 5)
        params, roles = {}, {}
        p, r = layers.init_embedding(keys[0], V, cfg.d_model)
        params["embed"], roles["embed"] = p, r
        p, r = transformer.init_stack(keys[1], cfg)
        params["decoder"], roles["decoder"] = p, r
        p, r = layers.init_rmsnorm(cfg.d_model)
        params["ln_f"], roles["ln_f"] = p, r
        if not cfg.tie_embeddings:
            p, r = layers.init_lm_head(keys[2], cfg.d_model, V)
            params["head"], roles["head"] = p, r
        if cfg.encoder is not None:
            enc_pat = (("B", "D"),)
            p, r = transformer.init_stack(
                keys[3], cfg, pattern=enc_pat,
                n_super=cfg.encoder.n_layers, first_k_dense=0)
            params["encoder"], roles["encoder"] = p, r
            p, r = layers.init_rmsnorm(cfg.d_model)
            params["ln_enc"], roles["ln_enc"] = p, r
        return params, roles

    def _memory(params, batch):
        if cfg.encoder is not None:
            enc, _ = transformer.apply_stack(params["encoder"],
                                             batch["enc_frames"].astype(DTYPE),
                                             cfg, pattern=(("B", "D"),))
            return layers.rmsnorm(params["ln_enc"], enc, cfg.norm_eps)
        if cfg.n_img_tokens:
            return batch["img_embed"].astype(DTYPE)
        return None

    def apply(params, batch):
        x = layers.embed(params["embed"], batch["tokens"]).astype(DTYPE)
        x = x * jnp.asarray(cfg.d_model ** 0.5, DTYPE)
        memory = _memory(params, batch)
        x, aux = transformer.apply_stack(params["decoder"], x, cfg,
                                         memory=memory)
        return layers.rmsnorm(params["ln_f"], x, cfg.norm_eps), aux

    def logits(params, hidden):
        if cfg.tie_embeddings:
            return hidden @ params["embed"]["table"].T
        return hidden @ params["head"]["w"]

    def init_caches(batch, seq):
        mem_len = 0
        if cfg.encoder is not None or cfg.n_img_tokens:
            mem_len = cfg.n_img_tokens or seq
        return transformer.init_caches(cfg, batch, seq, memory_len=mem_len)

    def decode_step(params, token, caches, position):
        """token: (B,1) i32. Returns (logits (B,1,V), new caches)."""
        x = layers.embed(params["embed"], token).astype(DTYPE)
        x = x * jnp.asarray(cfg.d_model ** 0.5, DTYPE)
        x, caches = transformer.decode_stack(params["decoder"], x, caches,
                                             position, cfg)
        h = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        return logits(params, h), caches

    def input_specs(shape: ShapeCfg):
        """ShapeDtypeStruct stand-ins for the entry-point batch (no alloc)."""
        B, S = shape.global_batch, shape.seq
        sds = jax.ShapeDtypeStruct
        if shape.kind in ("train", "prefill"):
            if cfg.encoder is not None:
                return {
                    "tokens": sds((B, cfg.encoder.dec_seq), jnp.int32),
                    "enc_frames": sds((B, S, cfg.d_model), DTYPE),
                    "labels": sds((B, cfg.encoder.dec_seq), jnp.int32),
                }
            batch = {"tokens": sds((B, S), jnp.int32),
                     "labels": sds((B, S), jnp.int32)}
            if cfg.n_img_tokens:
                batch["img_embed"] = sds((B, cfg.n_img_tokens, cfg.d_model),
                                         DTYPE)
            return batch
        # decode: one new token against a seq-length cache
        caches = jax.eval_shape(lambda: init_caches(B, S))
        return {"token": sds((B, 1), jnp.int32),
                "position": sds((), jnp.int32),
                "caches": caches}

    return Model(cfg, init, apply, logits, decode_step, init_caches,
                 input_specs)


def abstract_init(model: Model, rng=None):
    """(param ShapeDtypeStructs, roles) without allocating anything."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    captured = {}

    def f(k):
        p, r = model.init(k)
        captured["roles"] = r        # python-side, built during tracing
        return p

    shapes = jax.eval_shape(f, rng)
    return shapes, captured["roles"]


# ---------------------------------------------------------------------------
# Parameter / FLOP accounting (analytic; used by roofline + MODEL_FLOPS)
# ---------------------------------------------------------------------------

def _block_params(cfg: ModelConfig, mixer: str, ffn: str,
                  active_only: bool = False) -> int:
    D = cfg.d_model
    n = 2 * D                       # ln1 + ln2-ish
    if mixer == "M":
        d_inner, H = mamba.dims(D, cfg.ssm)
        G, N = cfg.ssm.n_groups, cfg.ssm.d_state
        d_proj = 2 * d_inner + 2 * G * N + H
        n += D * d_proj + cfg.ssm.conv * (d_inner + 2 * G * N) + 3 * H \
            + d_inner + d_inner * D
    else:
        a = cfg.attn
        n += D * a.n_heads * a.head_dim * 2 + D * a.n_kv * a.head_dim * 2
        if mixer == "C":
            n += D * a.n_heads * a.head_dim * 2 + D * a.n_kv * a.head_dim * 2
    if ffn == "D":
        mult = 3 if cfg.swiglu else 2
        n += mult * D * cfg.d_ff
    elif ffn == "E":
        m = cfg.moe
        mult = 3 if cfg.swiglu else 2
        per_expert = mult * D * m.d_expert
        routed = (m.top_k if active_only else m.n_routed) * per_expert
        n += routed + m.n_shared * per_expert + D * m.n_routed
    return n


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    n = cfg.padded_vocab * cfg.d_model          # embedding
    if not cfg.tie_embeddings:
        n += cfg.padded_vocab * cfg.d_model     # head
    for i in range(cfg.first_k_dense):
        n += _block_params(cfg, cfg.pattern[0][0], "D", active_only)
    for mx, ff in cfg.pattern:
        n += cfg.n_super * _block_params(cfg, mx, ff, active_only)
    if cfg.encoder is not None:
        n += cfg.encoder.n_layers * _block_params(cfg, "B", "D", active_only)
    return n


def model_flops(cfg: ModelConfig, shape: ShapeCfg) -> float:
    """MODEL_FLOPS: 6*N*D for training (N = active params, D = tokens);
    2*N*D for inference steps; attention quadratic term added explicitly."""
    n_active = count_params(cfg, active_only=True)
    B, S = shape.global_batch, shape.seq
    if shape.kind == "train":
        tokens = B * S
        flops = 6.0 * n_active * tokens
        mult = 3.0
    elif shape.kind == "prefill":
        tokens = B * S
        flops = 2.0 * n_active * tokens
        mult = 1.0
    else:  # decode: one token, but attention reads the full cache
        tokens = B
        flops = 2.0 * n_active * tokens
        mult = 1.0
    # attention score+value FLOPs
    a = cfg.attn
    attn_layers = sum(1 for mx, _ in cfg.pattern if mx in "AGWLCB")
    n_attn = cfg.n_super * attn_layers + cfg.first_k_dense
    if cfg.encoder is not None and shape.kind != "decode":
        n_attn += cfg.encoder.n_layers
    hdim = a.n_heads * a.head_dim
    if shape.kind == "decode":
        ctx = S
        flops += mult * n_attn * 4.0 * B * ctx * hdim
    else:
        per_layer = 0.0
        for mx, _ in cfg.pattern:
            if mx in ("W", "L"):
                ctx = min(a.window, S)
            elif mx in ("A", "G", "C", "B"):
                ctx = S / 2  # causal average
            else:
                continue
            per_layer += 4.0 * B * S * ctx * hdim
        flops += mult * cfg.n_super * per_layer
    return flops
