"""Attention: GQA with full / sliding-window / local / bidirectional / cross
variants, memory-sane chunked ("flash-scan") computation for long sequences,
and single-token decode against (sequence-sharded) KV caches.

Paths:
  attend()         dense einsum with mask      — short sequences / smoke tests
  attend_chunked() nested lax.scan with online softmax — long prefill; for
                   windowed attention the KV window is dynamic-sliced per query
                   chunk, so HLO FLOPs stay linear in S.
  decode_attend()  one new token vs cache; softmax reductions run sharded over
                   the cache's sequence axis (flash-decoding style SP).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import AttnCfg
from repro.models import layers
from repro.models.layers import DTYPE, _normal

NEG_INF = -1e9
CHUNK_Q = 512
CHUNK_KV = 1024
DENSE_MAX_S = 2048


def init_attention(key, d_model: int, cfg: AttnCfg):
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d_model ** -0.5
    H, K, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    params = {
        "wq": _normal(kq, (d_model, H * hd), s),
        "wk": _normal(kk, (d_model, K * hd), s),
        "wv": _normal(kv, (d_model, K * hd), s),
        "wo": _normal(ko, (H * hd, d_model), (H * hd) ** -0.5),
    }
    roles = {
        "wq": ("embed", "qheads"), "wk": ("embed", "kvheads"),
        "wv": ("embed", "kvheads"), "wo": ("qheads", "embed"),
    }
    if cfg.qk_norm:
        params["q_norm"] = jnp.ones((hd,), DTYPE)
        params["k_norm"] = jnp.ones((hd,), DTYPE)
        roles["q_norm"] = (None,)
        roles["k_norm"] = (None,)
    return params, roles


def _qkv(params, x, cfg: AttnCfg, positions, rope: bool = True):
    B, S, _ = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, S, H, hd)
    k = (x @ params["wk"]).reshape(B, S, K, hd)
    v = (x @ params["wv"]).reshape(B, S, K, hd)
    if cfg.qk_norm:
        q = layers.l2norm(q) * params["q_norm"]
        k = layers.l2norm(k) * params["k_norm"]
    if rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _expand_kv(k, n_heads):
    """Broadcast kv heads to match query heads (GQA)."""
    B, S, K, hd = k.shape
    rep = n_heads // K
    return jnp.repeat(k, rep, axis=2) if rep > 1 else k


def _mask(sq, skv, q_off, kind: str, window: int):
    qi = q_off + jnp.arange(sq)[:, None]
    ki = jnp.arange(skv)[None, :]
    if kind == "bidir":
        return jnp.ones((sq, skv), bool)
    m = ki <= qi
    if kind == "window":
        m &= ki > qi - window
    return m


def attend(q, k, v, kind: str, window: int, scale: float, q_off=0):
    """Dense attention. q: (B,Sq,H,hd), k/v: (B,Skv,H,hd)."""
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    m = _mask(q.shape[1], k.shape[1], q_off, kind, window)
    logits = jnp.where(m[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def attend_chunked(q, k, v, kind: str, window: int, scale: float,
                   kv_valid: int | None = None):
    """Online-softmax chunked attention (flash-style, pure JAX).

    Full/bidir: outer scan over query chunks, inner scan over all KV chunks
    with causal masking. Windowed ('window'): per query chunk only the KV
    window is dynamic-sliced, keeping compiled FLOPs linear in S.
    Supports Sq != Skv (cross attention): KV is padded to a chunk multiple and
    positions >= kv_valid are masked.
    """
    B, S, H, hd = q.shape
    S_kv = k.shape[1]
    if kind != "bidir":
        assert S_kv == S, "causal/windowed attention needs Sq == Skv"
    kv_valid = kv_valid if kv_valid is not None else S_kv
    cq = min(CHUNK_Q, S)
    assert S % cq == 0
    nq = S // cq

    if kind == "window" and window + cq < S:
        kv_span = ((window + cq + CHUNK_KV - 1) // CHUNK_KV) * CHUNK_KV
        kv_span = min(kv_span, S)
        kp = jnp.pad(k, ((0, 0), (kv_span, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (kv_span, 0), (0, 0), (0, 0)))

        @jax.checkpoint
        def q_block(i):
            q_i = jax.lax.dynamic_slice_in_dim(q, i * cq, cq, axis=1)
            k_i = jax.lax.dynamic_slice_in_dim(kp, i * cq, kv_span + cq, axis=1)
            v_i = jax.lax.dynamic_slice_in_dim(vp, i * cq, kv_span + cq, axis=1)
            # positions of k_i run from i*cq - kv_span .. i*cq + cq (pre-pad space)
            logits = jnp.einsum("bqhd,bkhd->bhqk", q_i, k_i).astype(jnp.float32) * scale
            qi = (i * cq + jnp.arange(cq))[:, None]
            ki = (i * cq - kv_span + jnp.arange(kv_span + cq))[None, :]
            m = (ki <= qi) & (ki > qi - window) & (ki >= 0)
            logits = jnp.where(m[None, None], logits, NEG_INF)
            p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
            return jnp.einsum("bhqk,bkhd->bqhd", p, v_i)

        out = jax.lax.map(q_block, jnp.arange(nq))          # (nq,B,cq,H,hd)
        return jnp.moveaxis(out, 0, 1).reshape(B, S, H, hd)

    ckv = min(CHUNK_KV, S_kv) if S_kv >= CHUNK_KV else S_kv
    pad_kv = (-S_kv) % ckv
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    nkv = k.shape[1] // ckv
    kc = k.reshape(B, nkv, ckv, H, hd)
    vc = v.reshape(B, nkv, ckv, H, hd)
    masked_kv = kv_valid < nkv * ckv

    @jax.checkpoint     # recompute the online-softmax pass in backward; the
    def q_block(i):     # inner scan would otherwise save per-step P blocks
        q_i = jax.lax.dynamic_slice_in_dim(q, i * cq, cq, axis=1)
        q_pos = i * cq + jnp.arange(cq)

        def kv_step(carry, j):
            acc, m_run, l_run = carry
            k_j, v_j = kc[:, j], vc[:, j]
            logits = jnp.einsum("bqhd,bkhd->bhqk", q_i, k_j).astype(jnp.float32) * scale
            k_pos = j * ckv + jnp.arange(ckv)
            if kind != "bidir":
                msk = k_pos[None, :] <= q_pos[:, None]
                logits = jnp.where(msk[None, None], logits, NEG_INF)
            if masked_kv:
                logits = jnp.where((k_pos < kv_valid)[None, None, None],
                                   logits, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc = (acc * corr[..., None]
                   + jnp.einsum("bhqk,bkhd->bhqd", p.astype(q.dtype), v_j))
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((B, H, cq, hd), jnp.float32)
        m0 = jnp.full((B, H, cq), NEG_INF)
        l0 = jnp.zeros((B, H, cq))
        (acc, m_run, l_run), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                              jnp.arange(nkv))
        out = acc / jnp.maximum(l_run, 1e-20)[..., None]
        return jnp.moveaxis(out, 1, 2).astype(q.dtype)     # (B,cq,H,hd)

    out = jax.lax.map(q_block, jnp.arange(nq))              # (nq,B,cq,H,hd)
    return jnp.moveaxis(out, 0, 1).reshape(B, S, H, hd)


def self_attention(params, x, cfg: AttnCfg, kind: str, positions=None,
                   rope: bool = True):
    """kind: 'causal' | 'window' | 'bidir'. Returns (B,S,D)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q, k, v = _qkv(params, x, cfg, positions, rope)
    k = _expand_kv(k, cfg.n_heads)
    v = _expand_kv(v, cfg.n_heads)
    scale = cfg.softmax_scale or cfg.head_dim ** -0.5
    if S <= DENSE_MAX_S:
        o = attend(q, k, v, kind, cfg.window, scale)
    else:
        o = attend_chunked(q, k, v, kind, cfg.window, scale)
    return o.reshape(B, S, cfg.n_heads * cfg.head_dim) @ params["wo"]


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder / VLM image layers)
# ---------------------------------------------------------------------------

def cross_attention(params, x, memory, cfg: AttnCfg):
    """x: (B,Sq,D) queries; memory: (B,Skv,D) or precomputed (k,v) tuple."""
    B, Sq, _ = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, Sq, H, hd)
    if isinstance(memory, tuple):
        k, v = memory
    else:
        Skv = memory.shape[1]
        k = (memory @ params["wk"]).reshape(B, Skv, K, hd)
        v = (memory @ params["wv"]).reshape(B, Skv, K, hd)
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scale = cfg.softmax_scale or hd ** -0.5
    if Sq <= 16 or max(Sq, k.shape[1]) <= DENSE_MAX_S:
        # short query blocks (incl. single-token decode): dense logits are
        # (B,H,Sq,Skv) — small enough even for 32k memories
        o = attend(q, k, v, "bidir", 0, scale)
    else:
        pad_q = (-Sq) % CHUNK_Q if Sq > CHUNK_Q else 0
        if pad_q:
            q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        o = attend_chunked(q, k, v, "bidir", 0, scale,
                           kv_valid=k.shape[1])[:, :Sq]
    return o.reshape(B, Sq, H * hd) @ params["wo"]


# ---------------------------------------------------------------------------
# Decode (single new token vs KV cache)
# ---------------------------------------------------------------------------

def decode_attend(params, x, cache_k, cache_v, position, cfg: AttnCfg,
                  window: int = 0):
    """x: (B,1,D); cache_k/v: (B,S,K,hd) with valid entries < position.

    The softmax max/sum reductions contract over the cache sequence axis, so a
    sequence-sharded cache (PartitionSpec on S) runs flash-decoding style under
    GSPMD (partial max/sum + all-reduce).
    Returns (out (B,1,D), new_k (B,1,K,hd), new_v).
    """
    B = x.shape[0]
    H, K, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, 1, H, hd)
    k_new = (x @ params["wk"]).reshape(B, 1, K, hd)
    v_new = (x @ params["wv"]).reshape(B, 1, K, hd)
    if cfg.qk_norm:
        q = layers.l2norm(q) * params["q_norm"]
        k_new = layers.l2norm(k_new) * params["k_norm"]
    pos = jnp.full((1,), position)
    q = layers.apply_rope(q, pos, cfg.rope_theta)
    k_new = layers.apply_rope(k_new, pos, cfg.rope_theta)

    S = cache_k.shape[1]
    scale = cfg.softmax_scale or hd ** -0.5
    rep = H // K
    qg = q.reshape(B, 1, K, rep, hd)
    # logits over the (sharded) cache axis, fp32
    logits = jnp.einsum("bokrd,bskd->bkrs", qg, cache_k).astype(jnp.float32) * scale
    new_logit = jnp.einsum("bokrd,bokd->bkro", qg, k_new).astype(jnp.float32) * scale
    ki = jnp.arange(S)
    valid = ki[None, None, None, :] < position
    if window:
        valid &= ki[None, None, None, :] >= position - window
    logits = jnp.where(valid, logits, NEG_INF)
    m = jnp.maximum(jnp.max(logits, axis=-1, keepdims=True), new_logit)
    p = jnp.exp(logits - m)
    p_new = jnp.exp(new_logit - m)
    denom = jnp.sum(p, axis=-1, keepdims=True) + p_new
    ctx = (jnp.einsum("bkrs,bskd->bkrd", (p / denom).astype(x.dtype), cache_v)
           + (p_new / denom).astype(x.dtype) * v_new.reshape(B, 1, K, 1, hd)[:, 0])
    out = ctx.reshape(B, 1, H * hd) @ params["wo"]
    return out, k_new, v_new
