"""Mixture-of-experts FFN: top-k token-choice routing with capacity-bounded
sort-based dispatch (gather -> per-expert dense matmul -> weighted scatter).

This is the production-style "dropping" MoE: capacity C per expert is static
(C = ceil(T_group * k / E * capacity_factor)), tokens beyond capacity are
dropped (standard at scale). Expert weights are stacked (E, ...) so they shard
over the model axis (expert parallelism) when E % mesh_model == 0, otherwise
the policy shards d_expert inside each expert (TP-in-expert, e.g. mixtral's
8 experts on a 16-way axis).

Dispatch carries an explicit group dimension G (cfg.dispatch_groups). With
G = the data-parallel degree, routing/sort/gather/scatter are shard-local and
the G axis of every heavy tensor is pinned to the data axis via the
activation-sharding context — without the pin, GSPMD replicates the dispatch
buffers and all-reduces their gradients through the layer scan (§Perf A1/A2).

Router styles:
  mixtral/jamba : top-k over logits, softmax over the selected k
  deepseek      : softmax over all experts, top-k, renormalize
Shared experts (deepseek) run densely on every token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoECfg
from repro.models.layers import DTYPE, _normal
from repro.sharding.context import constrain


def init_moe(key, d_model: int, cfg: MoECfg, swiglu: bool = True):
    ks = jax.random.split(key, 8)
    E, F = cfg.n_routed, cfg.d_expert
    s_in, s_out = d_model ** -0.5, F ** -0.5
    params = {
        "router": _normal(ks[0], (d_model, E), s_in).astype(jnp.float32),
        "w_gate": _normal(ks[1], (E, d_model, F), s_in),
        "w_up": _normal(ks[2], (E, d_model, F), s_in),
        "w_down": _normal(ks[3], (E, F, d_model), s_out),
    }
    roles = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "expert_ff"),
        "w_up": ("experts", "embed", "expert_ff"),
        "w_down": ("experts", "expert_ff", "embed"),
    }
    if cfg.n_shared:
        Fs = cfg.n_shared * F
        params["ws_gate"] = _normal(ks[4], (d_model, Fs), s_in)
        params["ws_up"] = _normal(ks[5], (d_model, Fs), s_in)
        params["ws_down"] = _normal(ks[6], (Fs, d_model), Fs ** -0.5)
        roles["ws_gate"] = ("embed", "ff")
        roles["ws_up"] = ("embed", "ff")
        roles["ws_down"] = ("ff", "embed")
    return params, roles


def _capacity(n_tokens: int, cfg: MoECfg) -> int:
    c = int(n_tokens * cfg.top_k / cfg.n_routed * cfg.capacity_factor)
    return max((c + 7) // 8 * 8, 8)


def moe_ffn(params, x, cfg: MoECfg, swiglu: bool = True):
    """x: (B, S, D) -> (B, S, D), plus aux metrics dict."""
    B, S, D = x.shape
    T = B * S
    G = max(cfg.dispatch_groups, 1)
    if T % G or (T // G) * cfg.top_k < cfg.n_routed:
        G = 1
    if G > 1:
        xg = constrain(x.reshape(G, T // G, 1, D), ("data", None, None, None))
        yg, aux = jax.vmap(
            lambda xs: _moe_dispatch(params, xs, cfg, swiglu))(xg)
        yg = constrain(yg, ("data", None, None, None))
        return yg.reshape(B, S, D), jax.tree.map(jnp.mean, aux)
    return _moe_dispatch(params, x, cfg, swiglu)


def _moe_dispatch(params, x, cfg: MoECfg, swiglu: bool = True):
    """Single-group dispatch with flat 1-D indices (the 2-D grouped-index
    variant lowered to pathological scatters under GSPMD — §Perf C4)."""
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_routed, cfg.top_k
    C = _capacity(T, cfg)
    xf = x.reshape(T, D)

    logits = (xf.astype(jnp.float32) @ params["router"])          # (T, E)
    if cfg.router_pre_softmax:
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, K)           # (T, K)
        gates = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    else:
        top_logits, expert_idx = jax.lax.top_k(logits, K)
        gates = jax.nn.softmax(top_logits, axis=-1)

    # --- sort-based dispatch with static capacity ---
    flat_e = expert_idx.reshape(T * K)                            # (TK,)
    flat_g = gates.reshape(T * K)
    flat_tok = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_tok[order], flat_g[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * K) - starts[se]                          # rank in expert
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)                   # overflow slot
    tok_of_slot = jnp.zeros((E * C + 1,), jnp.int32).at[slot].set(
        st.astype(jnp.int32))[:-1].reshape(E, C)
    gate_of_slot = jnp.zeros((E * C + 1,)).at[slot].set(sg)[:-1].reshape(E, C)
    valid_slot = jnp.zeros((E * C + 1,)).at[slot].set(
        keep.astype(jnp.float32))[:-1].reshape(E, C)

    xe = xf[tok_of_slot] * valid_slot[..., None].astype(x.dtype)  # (E, C, D)
    if swiglu:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, params["w_up"]))
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"])          # (E, C, D)

    w = (gate_of_slot * valid_slot)[..., None].astype(x.dtype)
    out = jnp.zeros((T, D), x.dtype).at[tok_of_slot.reshape(-1)].add(
        (ye * w).reshape(E * C, D))

    if cfg.n_shared:
        if swiglu:
            g = jax.nn.silu(xf @ params["ws_gate"])
            out = out + (g * (xf @ params["ws_up"])) @ params["ws_down"]
        else:
            out = out + jax.nn.gelu(xf @ params["ws_up"]) @ params["ws_down"]

    # load-balance aux (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=0)
    fe = jnp.zeros((E,)).at[flat_e].add(1.0) / (T * K)
    aux = {"lb_loss": E * jnp.sum(me * fe),
           "drop_frac": 1.0 - jnp.sum(valid_slot) / (T * K)}
    return out.reshape(B, S, D), aux
