"""Fundamental layers: RMSNorm, RoPE, SwiGLU/GELU MLP, embeddings.

Pure functions over explicit parameter dicts. Parameters are bf16; norms and
softmax accumulate in fp32. Every init_* returns (params, roles) where `roles`
mirrors the params tree with a tuple of semantic axis names per leaf — the
sharding policy maps roles -> PartitionSpec (see repro.sharding.policies).

Axis-role vocabulary:
  'embed'  d_model            'ff'      MLP hidden
  'vocab'  vocabulary         'qheads'  merged q heads*head_dim
  'kvheads' merged kv heads*head_dim    'experts' MoE expert axis
  'heads'  per-head axis      'inner'   mamba d_inner
  null     replicated
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any
DTYPE = jnp.bfloat16


def _normal(key, shape, scale):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(DTYPE)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), DTYPE)}, {"scale": ("embed",)}


@jax.custom_vjp
def _rmsnorm_core(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def _rmsnorm_fwd(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    y = (xf * inv).astype(x.dtype) * scale
    return y, (x, scale, inv)


def _rmsnorm_bwd(res, dy):
    """Exact grad computed in fp32, *returned in the input dtype*: without
    this, the fp32 internals leak into the backward graph and every
    tensor-parallel gradient all-reduce moves fp32 payloads (2x wire bytes —
    measured in §Perf B2)."""
    x, scale, inv = res
    xf = x.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)
    xhat = xf * inv
    dscale = jnp.sum(dyf * xhat.astype(jnp.float32),
                     axis=tuple(range(dy.ndim - 1))).astype(scale.dtype)
    dxhat = dyf * scale.astype(jnp.float32)
    d = x.shape[-1]
    dx = inv * (dxhat - xhat * jnp.mean(dxhat * xhat, axis=-1, keepdims=True))
    return dx.astype(x.dtype), dscale, None


_rmsnorm_core.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def rmsnorm(params, x, eps=1e-6):
    return _rmsnorm_core(x, params["scale"], eps)


def l2norm(x, eps=1e-6):
    """Per-head qk-norm (qwen3)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (S,) or broadcastable."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                   # (S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, swiglu: bool = True):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    if swiglu:
        params = {
            "w_gate": _normal(k1, (d_model, d_ff), s_in),
            "w_up": _normal(k2, (d_model, d_ff), s_in),
            "w_down": _normal(k3, (d_ff, d_model), s_out),
        }
        roles = {
            "w_gate": ("embed", "ff"), "w_up": ("embed", "ff"),
            "w_down": ("ff", "embed"),
        }
    else:
        params = {
            "w_up": _normal(k2, (d_model, d_ff), s_in),
            "w_down": _normal(k3, (d_ff, d_model), s_out),
        }
        roles = {"w_up": ("embed", "ff"), "w_down": ("ff", "embed")}
    return params, roles


def mlp(params, x, swiglu: bool = True):
    if swiglu:
        g = jax.nn.silu(x @ params["w_gate"])
        return ((g * (x @ params["w_up"])) @ params["w_down"]).astype(x.dtype)
    return (jax.nn.gelu(x @ params["w_up"]) @ params["w_down"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------

def init_embedding(key, vocab_padded: int, d_model: int):
    params = {"table": _normal(key, (vocab_padded, d_model), 1.0)}
    return params, {"table": ("vocab", "embed")}


def embed(params, tokens):
    return params["table"][tokens]


def init_lm_head(key, d_model: int, vocab_padded: int):
    params = {"w": _normal(key, (d_model, vocab_padded), d_model ** -0.5)}
    return params, {"w": ("embed", "vocab")}
