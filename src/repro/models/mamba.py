"""Mamba2 / SSD (state-space duality) mixer.

Chunked SSD algorithm (training/prefill): the sequence is split into chunks of
length Q; within a chunk the computation is a masked quadratic form (maps to
the MXU), across chunks a small recurrence over per-chunk states is carried by
`lax.scan`:

  dA_t = dt_t * A_h                          (A_h < 0, per head)
  seg  = within-chunk cumsum of dA
  intra:  Y_ij = (C_i . B_j) * exp(seg_i - seg_j) * dt_j  for i >= j
  states: S_c  = sum_j exp(seg_end - seg_j) * B_j (x) (dt_j * X_j)
  recur:  R_{c+1} = exp(sum_c dA) * R_c + S_c
  inter:  Y_i  += (C_i . R_c) * exp(seg_i)
  out:    y = (Y + D * x) -> RMSNorm gated by silu(z) -> out_proj

Decode: exact per-token recurrence on the (B, H, P, N) state plus a causal
depthwise-conv ring buffer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SSMCfg
from repro.models import layers
from repro.models.layers import DTYPE, _normal


def dims(d_model: int, cfg: SSMCfg):
    d_inner = cfg.expand * d_model
    n_heads = d_inner // cfg.head_dim
    return d_inner, n_heads


def init_mamba(key, d_model: int, cfg: SSMCfg):
    d_inner, H = dims(d_model, cfg)
    G, N = cfg.n_groups, cfg.d_state
    conv_ch = d_inner + 2 * G * N
    ks = jax.random.split(key, 5)
    d_in_proj = 2 * d_inner + 2 * G * N + H
    params = {
        "w_in": _normal(ks[0], (d_model, d_in_proj), d_model ** -0.5),
        "conv_w": _normal(ks[1], (cfg.conv, conv_ch), 0.5),
        "conv_b": jnp.zeros((conv_ch,), DTYPE),
        "a_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), DTYPE),
        "w_out": _normal(ks[2], (d_inner, d_model), d_inner ** -0.5),
    }
    roles = {
        "w_in": ("embed", "inner_proj"), "conv_w": (None, "conv_ch"),
        "conv_b": ("conv_ch",), "a_log": ("heads",), "d_skip": ("heads",),
        "dt_bias": ("heads",), "norm_scale": ("inner",),
        "w_out": ("inner", "embed"),
    }
    return params, roles


def _split_proj(proj, d_inner, G, N, H):
    z, x, B, C, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + G * N,
               2 * d_inner + 2 * G * N], axis=-1)
    return z, x, B, C, dt


def _causal_conv(x, w, b):
    """Depthwise causal conv along seq. x: (B,L,CH); w: (K,CH)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def mamba_block(params, hidden, cfg: SSMCfg, d_model: int):
    """hidden: (B, L, D) -> (B, L, D). Chunked SSD."""
    Bsz, L, _ = hidden.shape
    d_inner, H = dims(d_model, cfg)
    G, N, P = cfg.n_groups, cfg.d_state, cfg.head_dim
    Q = min(cfg.chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q

    proj = hidden @ params["w_in"]
    z, xBC_x, Bmat, Cmat, dt = _split_proj(proj, d_inner, G, N, H)
    xBC = jnp.concatenate([xBC_x, Bmat, Cmat], axis=-1)
    xBC = _causal_conv(xBC, params["conv_w"], params["conv_b"])
    x, Bmat, Cmat = jnp.split(xBC, [d_inner, d_inner + G * N], axis=-1)

    x = x.reshape(Bsz, L, H, P)
    Bmat = Bmat.reshape(Bsz, L, G, N).astype(jnp.float32)
    Cmat = Cmat.reshape(Bsz, L, G, N).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B,L,H)
    A = -jnp.exp(params["a_log"])                                      # (H,)

    # chunked views, scanned chunk-by-chunk (carries the state recurrence and
    # keeps the per-head decay tensor at one chunk's footprint)
    xc = jnp.moveaxis(x.reshape(Bsz, nc, Q, H, P), 1, 0).astype(jnp.float32)
    Bc = jnp.moveaxis(Bmat.reshape(Bsz, nc, Q, G, N)[:, :, :, 0], 1, 0)
    Cc = jnp.moveaxis(Cmat.reshape(Bsz, nc, Q, G, N)[:, :, :, 0], 1, 0)
    dtc = jnp.moveaxis(dt.reshape(Bsz, nc, Q, H), 1, 0)                # (nc,B,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_step(R, inp):
        x_i, B_i, C_i, dt_i = inp                  # (B,Q,H,P) (B,Q,N) .. (B,Q,H)
        dA = dt_i * A
        seg = jnp.cumsum(dA, axis=1)                                   # (B,Q,H)
        seg_end = seg[:, -1:, :]
        # intra-chunk masked quadratic (the "attention-like" SSD term)
        CB = jnp.einsum("bin,bjn->bij", C_i, B_i)                      # (B,Q,Q)
        decay = jnp.exp(jnp.clip(seg[:, :, None, :] - seg[:, None, :, :],
                                 -60.0, 0.0))                          # (B,Q,Q,H)
        att = CB[..., None] * decay * jnp.where(mask[None, ..., None], 1.0, 0.0)
        att = att * dt_i[:, None, :, :]                                # weight dt_j
        y_intra = jnp.einsum("bijh,bjhp->bihp", att, x_i)
        # contribution of the running inter-chunk state
        in_decay = jnp.exp(jnp.clip(seg, -60.0, 0.0))
        y_inter = jnp.einsum("bin,bih,bhnp->bihp", C_i, in_decay, R)
        # update running state
        state_w = jnp.exp(jnp.clip(seg_end - seg, -60.0, 0.0)) * dt_i
        S = jnp.einsum("bjn,bjh,bjhp->bhnp", B_i, state_w, x_i)
        R_new = (R * jnp.exp(jnp.clip(seg_end[:, 0, :], -60.0, 0.0))
                 [:, :, None, None] + S)
        return R_new, y_intra + y_inter

    init = jnp.zeros((Bsz, H, N, P))
    _, yc = jax.lax.scan(chunk_step, init, (xc, Bc, Cc, dtc))          # (nc,B,Q,H,P)
    y = jnp.moveaxis(yc, 0, 1).reshape(Bsz, L, H, P)
    y = y + params["d_skip"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(Bsz, L, d_inner).astype(hidden.dtype)
    y = layers.rmsnorm({"scale": params["norm_scale"]}, y * jax.nn.silu(z))
    return y @ params["w_out"]


# ---------------------------------------------------------------------------
# Decode (recurrent step)
# ---------------------------------------------------------------------------

def init_decode_state(batch: int, d_model: int, cfg: SSMCfg):
    d_inner, H = dims(d_model, cfg)
    conv_ch = d_inner + 2 * cfg.n_groups * cfg.d_state
    return {
        "ssm": jnp.zeros((batch, H, cfg.d_state, cfg.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv - 1, conv_ch), DTYPE),
    }


def mamba_decode_step(params, hidden, state, cfg: SSMCfg, d_model: int):
    """hidden: (B, 1, D); state: {ssm (B,H,N,P), conv (B,K-1,CH)}."""
    Bsz = hidden.shape[0]
    d_inner, H = dims(d_model, cfg)
    G, N, P = cfg.n_groups, cfg.d_state, cfg.head_dim

    proj = hidden[:, 0] @ params["w_in"]                               # (B, dproj)
    z, x, Bmat, Cmat, dt = _split_proj(proj, d_inner, G, N, H)
    xBC = jnp.concatenate([x, Bmat, Cmat], axis=-1)                    # (B, CH)
    window = jnp.concatenate([state["conv"], xBC[:, None]], axis=1)    # (B,K,CH)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, params["conv_w"])
                           + params["conv_b"])
    new_conv = window[:, 1:]
    x, Bmat, Cmat = jnp.split(conv_out, [d_inner, d_inner + G * N], axis=-1)

    x = x.reshape(Bsz, H, P).astype(jnp.float32)
    Bv = Bmat.reshape(Bsz, G, N)[:, 0].astype(jnp.float32)             # (B,N)
    Cv = Cmat.reshape(Bsz, G, N)[:, 0].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B,H)
    A = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt * A)                                            # (B,H)

    new_ssm = (state["ssm"] * decay[:, :, None, None]
               + jnp.einsum("bn,bh,bhp->bhnp", Bv, dt, x))
    y = jnp.einsum("bn,bhnp->bhp", Cv, new_ssm)
    y = y + params["d_skip"][None, :, None] * x
    y = y.reshape(Bsz, d_inner).astype(hidden.dtype)
    y = layers.rmsnorm({"scale": params["norm_scale"]},
                       y * jax.nn.silu(z))
    out = (y @ params["w_out"])[:, None]
    return out, {"ssm": new_ssm, "conv": new_conv}
