"""Block assembly: super-block patterns scanned over depth.

A block = (mixer, ffn) pair from the config pattern. Parameters for each
pattern position are stacked over the number of super-blocks and consumed by
`lax.scan`, so HLO size is independent of depth. Supports dense / MoE FFNs,
attention (full / SWA / local / global / bidirectional / +cross) and Mamba2
mixers, optional leading dense layers (deepseek), and a separate encoder stack
(whisper).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import layers, mamba, moe
from repro.models.layers import init_rmsnorm, rmsnorm

PyTree = Any

MIXER_KIND = {"A": "causal", "G": "causal", "W": "window", "L": "window",
              "B": "bidir", "C": "causal"}


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, mixer: str, ffn: str):
    keys = jax.random.split(key, 6)
    params, roles = {}, {}
    p, r = init_rmsnorm(cfg.d_model)
    params["ln1"], roles["ln1"] = p, r
    if mixer == "M":
        p, r = mamba.init_mamba(keys[0], cfg.d_model, cfg.ssm)
    else:
        p, r = attn_mod.init_attention(keys[0], cfg.d_model, cfg.attn)
    params["mixer"], roles["mixer"] = p, r
    if mixer == "C":
        p, r = attn_mod.init_attention(keys[1], cfg.d_model, cfg.attn)
        params["xattn"], roles["xattn"] = p, r
        p, r = init_rmsnorm(cfg.d_model)
        params["ln_x"], roles["ln_x"] = p, r
    if ffn == "D":
        p, r = init_rmsnorm(cfg.d_model)
        params["ln2"], roles["ln2"] = p, r
        p, r = layers.init_mlp(keys[2], cfg.d_model, cfg.d_ff, cfg.swiglu)
        params["ffn"], roles["ffn"] = p, r
    elif ffn == "E":
        p, r = init_rmsnorm(cfg.d_model)
        params["ln2"], roles["ln2"] = p, r
        p, r = moe.init_moe(keys[2], cfg.d_model, cfg.moe, cfg.swiglu)
        params["ffn"], roles["ffn"] = p, r
    return params, roles


def apply_block(params, x, cfg: ModelConfig, mixer: str, ffn: str,
                memory=None, positions=None):
    """x: (B,S,D). memory: (B,S_kv,D) for cross blocks. Returns (x, aux)."""
    from repro.sharding.context import constrain
    seq_spec = ("data", "model", None)
    aux = {}
    if cfg.seq_shard:
        x = constrain(x, seq_spec)
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    if mixer == "M":
        out = mamba.mamba_block(params["mixer"], h, cfg.ssm, cfg.d_model)
    else:
        # RoPE everywhere (whisper's sinusoidal absolute embeddings replaced by
        # RoPE — recorded simplification, DESIGN.md §6).
        out = attn_mod.self_attention(params["mixer"], h, cfg.attn,
                                      MIXER_KIND[mixer], positions)
    # named so the 'collectives' remat policy can save post-all-reduce
    # activations (remat's re-forward then skips the TP collectives, §Perf B4)
    x = x + jax.ad_checkpoint.checkpoint_name(out, "mixer_out")
    if mixer == "C" and memory is not None:
        h = rmsnorm(params["ln_x"], x, cfg.norm_eps)
        x = x + attn_mod.cross_attention(params["xattn"], h, memory, cfg.attn)
    if cfg.seq_shard:
        x = constrain(x, seq_spec)
    if ffn == "D":
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        x = x + jax.ad_checkpoint.checkpoint_name(
            layers.mlp(params["ffn"], h, cfg.swiglu), "ffn_out")
    elif ffn == "E":
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        out, moe_aux = moe.moe_ffn(params["ffn"], h, cfg.moe, cfg.swiglu)
        x = x + jax.ad_checkpoint.checkpoint_name(out, "ffn_out")
        aux["lb_loss"] = moe_aux["lb_loss"]
    if cfg.seq_shard:
        x = constrain(x, seq_spec)
    return x, aux


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------

def init_stack(key, cfg: ModelConfig, pattern=None, n_super=None,
               first_k_dense=None):
    pattern = pattern if pattern is not None else cfg.pattern
    n_super = n_super if n_super is not None else cfg.n_super
    first_k = cfg.first_k_dense if first_k_dense is None else first_k_dense
    params, roles = {"first": [], "supers": {}}, {"first": [], "supers": {}}
    keys = jax.random.split(key, len(pattern) + first_k)
    for i in range(first_k):
        p, r = init_block(keys[i], cfg, pattern[0][0], "D")
        params["first"].append(p)
        roles["first"].append(r)
    for i, (mx, ff) in enumerate(pattern):
        sub = jax.random.split(keys[first_k + i], n_super)
        fn = functools.partial(init_block, cfg=cfg, mixer=mx, ffn=ff)
        p = jax.vmap(lambda k: fn(k)[0])(sub)          # stacked (n_super, ...)
        _, r = init_block(keys[first_k + i], cfg, mx, ff)
        params["supers"][str(i)] = p
        roles["supers"][str(i)] = jax.tree.map(
            lambda t: ("layers",) + t, r,
            is_leaf=lambda t: isinstance(t, tuple))
    return params, roles


def apply_stack(params, x, cfg: ModelConfig, pattern=None, memory=None,
                positions=None):
    pattern = pattern if pattern is not None else cfg.pattern
    aux_sum = jnp.zeros(())
    for i, p in enumerate(params["first"]):
        x, aux = apply_block(p, x, cfg, pattern[0][0], "D", memory, positions)

    def super_block(carry, block_params):
        x, aux_sum = carry
        for i, (mx, ff) in enumerate(pattern):
            x, aux = apply_block(block_params[str(i)], x, cfg, mx, ff,
                                 memory, positions)
            if "lb_loss" in aux:
                aux_sum = aux_sum + aux["lb_loss"]
        return (x, aux_sum), None

    if cfg.remat != "none":
        policy = None                       # 'full': recompute everything
        if cfg.remat == "block":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        elif cfg.remat == "collectives":
            # save post-all-reduce block outputs: the backward re-forward
            # recomputes matmuls but never re-runs TP collectives
            policy = jax.checkpoint_policies.save_only_these_names(
                "mixer_out", "ffn_out")
        super_block = jax.checkpoint(super_block, policy=policy)
    (x, aux_sum), _ = jax.lax.scan(super_block, (x, aux_sum),
                                   params["supers"])
    return x, {"lb_loss": aux_sum}


# ---------------------------------------------------------------------------
# Decode stacks (single-token, with caches)
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, seq: int, pattern=None,
                n_super=None, memory_len: int = 0):
    """ShapeDtype-compatible cache pytree for one decoder stack."""
    pattern = pattern if pattern is not None else cfg.pattern
    n_super = n_super if n_super is not None else cfg.n_super
    K, hd = cfg.attn.n_kv, cfg.attn.head_dim
    caches = {"first": [], "supers": {}}
    window = cfg.attn.window

    for i in range(cfg.first_k_dense):
        caches["first"].append(
            {"k": jnp.zeros((batch, seq, K, hd), layers.DTYPE),
             "v": jnp.zeros((batch, seq, K, hd), layers.DTYPE)})
    for i, (mx, ff) in enumerate(pattern):
        if mx == "M":
            d_inner, H = mamba.dims(cfg.d_model, cfg.ssm)
            conv_ch = d_inner + 2 * cfg.ssm.n_groups * cfg.ssm.d_state
            c = {"ssm": jnp.zeros((n_super, batch, H, cfg.ssm.d_state,
                                   cfg.ssm.head_dim), jnp.float32),
                 "conv": jnp.zeros((n_super, batch, cfg.ssm.conv - 1, conv_ch),
                                   layers.DTYPE)}
        else:
            S = min(seq, window) if mx in ("W", "L") and window else seq
            c = {"k": jnp.zeros((n_super, batch, S, K, hd), layers.DTYPE),
                 "v": jnp.zeros((n_super, batch, S, K, hd), layers.DTYPE)}
            if mx == "C" and memory_len:
                c["xk"] = jnp.zeros((n_super, batch, memory_len, K, hd),
                                    layers.DTYPE)
                c["xv"] = jnp.zeros((n_super, batch, memory_len, K, hd),
                                    layers.DTYPE)
        caches["supers"][str(i)] = c
    return caches


def _decode_attn_block(params, x, cache, position, cfg: ModelConfig, mixer):
    """Windowed mixers keep a ring-buffer cache of size `window`: every live
    entry is inside the window by construction, so the attention mask only
    needs the fill count (min(position, S))."""
    windowed = mixer in ("W", "L") and cfg.attn.window
    S = cache["k"].shape[1]
    wpos = position % S if windowed else position
    eff_pos = jnp.minimum(position, S) if windowed else position
    out, k_new, v_new = attn_mod.decode_attend(
        params["mixer"], rmsnorm(params["ln1"], x, cfg.norm_eps),
        cache["k"], cache["v"], eff_pos, cfg.attn, window=0)
    x = x + out
    new_cache = dict(cache)
    upd = lambda c, n: jax.lax.dynamic_update_slice_in_dim(
        c, n.astype(c.dtype), wpos, axis=1)
    new_cache["k"] = upd(cache["k"], k_new)
    new_cache["v"] = upd(cache["v"], v_new)
    if mixer == "C" and "xk" in cache:
        h = rmsnorm(params["ln_x"], x, cfg.norm_eps)
        x = x + attn_mod.cross_attention(params["xattn"], h,
                                         (cache["xk"], cache["xv"]), cfg.attn)
    return x, new_cache


def decode_block(params, x, cache, position, cfg: ModelConfig, mixer, ffn):
    if mixer == "M":
        h = rmsnorm(params["ln1"], x, cfg.norm_eps)
        out, new_state = mamba.mamba_decode_step(params["mixer"], h, cache,
                                                 cfg.ssm, cfg.d_model)
        x = x + out
        new_cache = new_state
    else:
        x, new_cache = _decode_attn_block(params, x, cache, position, cfg,
                                          mixer)
    if ffn == "D":
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        x = x + layers.mlp(params["ffn"], h, cfg.swiglu)
    elif ffn == "E":
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        out, _ = moe.moe_ffn(params["ffn"], h, cfg.moe, cfg.swiglu)
        x = x + out
    return x, new_cache


def decode_stack(params, x, caches, position, cfg: ModelConfig, pattern=None):
    """Single-token decode through the stack.

    Uses fori_loop with the stacked caches held in the loop *carry* and
    updated in place (`.at[i].set`): XLA aliases while-loop carries, so the
    multi-GB KV/SSM caches live in ONE buffer. A scan with caches as xs/ys
    double-buffers them (measured +40% decode residency, §Perf D2).
    """
    pattern = pattern if pattern is not None else cfg.pattern
    new_first = []
    for p, c in zip(params["first"], caches["first"]):
        x, nc = decode_block(p, x, c, position, cfg, pattern[0][0], "D")
        new_first.append(nc)

    def body(i, carry):
        x, cache_st = carry
        for j, (mx, ff) in enumerate(pattern):
            bp = jax.tree.map(lambda p: p[i], params["supers"][str(j)])
            bc = jax.tree.map(lambda c: c[i], cache_st[str(j)])
            x, nc = decode_block(bp, x, bc, position, cfg, mx, ff)
            cache_st = dict(cache_st)
            cache_st[str(j)] = jax.tree.map(
                lambda c, n: c.at[i].set(n.astype(c.dtype)),
                cache_st[str(j)], nc)
        return (x, cache_st)

    x, new_supers = jax.lax.fori_loop(0, cfg.n_super, body,
                                      (x, caches["supers"]))
    return x, {"first": new_first, "supers": new_supers}
