"""Architecture zoo: composable pure-JAX model definitions."""
from repro.models.model import Model, build_model, count_params, model_flops  # noqa: F401
