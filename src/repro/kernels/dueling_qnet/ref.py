"""Pure-jnp oracle for the fused dueling-qnet kernel."""
from __future__ import annotations

import jax.numpy as jnp


def dueling_qnet_ref(x, w0, b0, w1, b1, wv, bv, wa, ba):
    x = x.astype(jnp.float32)
    h = jnp.maximum(x @ w0.astype(jnp.float32) + b0, 0.0)
    h = jnp.maximum(h @ w1.astype(jnp.float32) + b1, 0.0)
    v = h @ wv.astype(jnp.float32) + bv
    a = h @ wa.astype(jnp.float32) + ba
    return v + a - jnp.mean(a, axis=-1, keepdims=True)
