"""Pallas TPU kernel: fused dueling-DQN inference (paper §5.2 RL accelerator).

The paper proposes a dedicated accelerator (FA3C-style) for the agent's
dueling network. On TPU the analogue is a single fused kernel: the whole MLP
(state -> h1 -> h2 -> {V, A} -> Q = V + A - mean(A)) runs out of VMEM for a
batch tile, so Q-inference for a replay batch is one kernel launch — no HBM
round-trips between layers.

Weights for the production agent (state_dim<=256, hidden 128) total < 200 KB —
far under the ~16 MB VMEM budget, so all weights live in VMEM for every tile
(BlockSpec index maps pin them to block 0). Batch is tiled at 128 rows to
align with the MXU's 128-lane systolic dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BATCH_TILE = 128


def _qnet_kernel(x_ref, w0_ref, b0_ref, w1_ref, b1_ref, wv_ref, bv_ref,
                 wa_ref, ba_ref, q_ref):
    x = x_ref[...].astype(jnp.float32)
    h = jnp.maximum(jnp.dot(x, w0_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)
                    + b0_ref[...], 0.0)
    h = jnp.maximum(jnp.dot(h, w1_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)
                    + b1_ref[...], 0.0)
    v = jnp.dot(h, wv_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32) + bv_ref[...]   # (Bt, 1)
    a = jnp.dot(h, wa_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32) + ba_ref[...]   # (Bt, A)
    q = v + a - jnp.mean(a, axis=-1, keepdims=True)
    q_ref[...] = q.astype(q_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dueling_qnet_fused(x, w0, b0, w1, b1, wv, bv, wa, ba, *,
                       interpret: bool = False):
    """x: (B, S) padded so B % BATCH_TILE == 0. Returns Q: (B, A)."""
    B, S = x.shape
    A = wa.shape[1]
    H1, H2 = w0.shape[1], w1.shape[1]
    assert B % BATCH_TILE == 0, B
    grid = (B // BATCH_TILE,)
    full = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    return pl.pallas_call(
        _qnet_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BATCH_TILE, S), lambda i: (i, 0)),
            full((S, H1)), full((H1,)),
            full((H1, H2)), full((H2,)),
            full((H2, 1)), full((1,)),
            full((H2, A)), full((A,)),
        ],
        out_specs=pl.BlockSpec((BATCH_TILE, A), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, A), jnp.float32),
        interpret=interpret,
    )(x, w0, b0, w1, b1, wv, bv, wa, ba)
