"""Jitted wrapper: pads batch/feature dims to tile boundaries and dispatches
to the Pallas kernel (interpret mode on CPU; compiled on TPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dueling_qnet.kernel import BATCH_TILE, dueling_qnet_fused


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def qnet_forward(params: dict, states: jnp.ndarray,
                 interpret: bool | None = None) -> jnp.ndarray:
    """params: repro.core.dqn dueling param dict (w0,b0,w1,b1,w_v,b_v,w_a,b_a).
    states: (B, state_dim). Returns Q (B, n_actions)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, S = states.shape
    pad_b = (-B) % BATCH_TILE
    x = jnp.pad(states, ((0, pad_b), (0, 0)))
    q = dueling_qnet_fused(
        x, params["w0"], params["b0"], params["w1"], params["b1"],
        params["w_v"], params["b_v"], params["w_a"], params["b_a"],
        interpret=interpret)
    return q[:B]
