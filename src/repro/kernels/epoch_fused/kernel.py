"""Pallas kernel for the fused epoch core.

One `pl.pallas_call` covers the epoch simulation core: the seed-invariant
shared stage (row-buffer stamp-and-count, PEI top_k threshold + hot flags,
access-EMA update, touch counts) and/or the schedule/route/count stage
(effective-table gathers, technique + AIMM-remap scheduling, one-hot-matmul
link loads and per-cube counts against the topology's pair-flattened
`routes_flat`/`hops_flat` layouts).  Stage selection is static
(`run_shared`/`run_route`), mirroring `BodyFlags`: the seed-shared epoch
driver calls the shared stage once per lane and the route stage once per
seed cell, while the unshared path fuses both into a single call.

Batching contract: the wrappers are written for ONE lane/cell (no leading
batch axis).  `pl.pallas_call` registers a vmap batching rule, so the
engine's per-lane `jax.vmap` / nested (lane, seed) vmap batches the kernel
by adding grid dimensions — no kernel-side BlockSpecs are needed, and
trace-time-constant operands (topology tensors) ride along unbatched.

The kernel body executes the exact same stage functions as the jnp dispatch
path (`ref.shared_stage` / `ref.route_stage_onehot` / `ref.tom_stage_loop`),
so interpret-mode output is bit-identical to the jnp path on the pinned
engine goldens (tests/test_pallas_parity.py).  Remaining work for the
real-TPU (Mosaic) lane: the P-indexed gathers/scatters and `lax.top_k`
inside the body lower cleanly in interpreter mode everywhere but still need
a tiled formulation for Mosaic — tracked in ROADMAP.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.epoch_fused import ref
from repro.kernels.epoch_fused.ref import RouteParts, SharedParts


def _s(x, dtype):
    """Scalar -> (1,)-shaped kernel operand."""
    return jnp.asarray(x, dtype).reshape((1,))


def fused_epoch_call(dest, src1, src2, valid, *,
                     epochs=None, rb_stamp=None, page_ema=None, n_pages=None,
                     pei_idx=None, rb_winner=None, pei_hot1=None,
                     pei_hot2=None, eff_table=None, compute_remap=None,
                     technique=None, is_aimm=None, pending_mig_loads=None,
                     routes_flat=None, hops_flat=None, nearest_mc=None,
                     pei_k: int = 0, aimm: bool = False,
                     run_shared: bool = True, run_route: bool = True,
                     n_mcs: int = 0, packet_flits: float = 0.0,
                     interpret: bool = True
                     ) -> tuple[SharedParts | None, RouteParts | None]:
    """Run the fused epoch core for one lane/cell; see module doc.

    Operand presence follows the static stage/feature flags exactly (like
    `BodyFlags`): compiled-out machinery never even enters the kernel.
    Returns (SharedParts | None, RouteParts | None)."""
    assert run_shared or run_route
    W = dest.shape[0]
    pei = pei_k > 0

    ins: list[tuple[str, jnp.ndarray]] = [
        ("dest", dest), ("src1", src1), ("src2", src2), ("valid", valid)]
    outs: list[tuple[str, tuple, jnp.dtype]] = []
    if run_shared:
        P = rb_stamp.shape[0] - 1
        ins += [("epochs", _s(epochs, jnp.float32)), ("rb_stamp", rb_stamp)]
        if pei:
            ins += [("page_ema", page_ema),
                    ("n_pages", _s(n_pages, jnp.int32)),
                    ("pei_idx", _s(pei_idx, jnp.int32))]
        outs += [("rb_stamp", (P + 1,), jnp.int32),
                 ("rb_winner", (3 * W,), jnp.bool_)]
        if pei:
            outs += [("page_ema", (P,), jnp.float32),
                     ("pei_hot1", (W,), jnp.bool_),
                     ("pei_hot2", (W,), jnp.bool_)]
        if aimm:
            outs += [("touch_cnt", (P,), jnp.float32)]
    elif run_route:
        # Winners (and PEI hot flags) were computed by the per-lane shared
        # call; the per-cell route call takes them as inputs.
        ins += [("rb_winner", rb_winner)]
        if pei:
            ins += [("pei_hot1", pei_hot1), ("pei_hot2", pei_hot2)]
    if run_route:
        C = nearest_mc.shape[0]
        L = pending_mig_loads.shape[0]
        ins += [("eff_table", eff_table),
                ("technique", _s(technique, jnp.int32)),
                ("pending_mig_loads", pending_mig_loads),
                ("routes_flat", routes_flat), ("hops_flat", hops_flat),
                ("nearest_mc", nearest_mc)]
        if aimm:
            ins += [("compute_remap", compute_remap),
                    ("is_aimm", _s(is_aimm, jnp.bool_))]
        outs += [("ccube", (W,), jnp.int32), ("loads", (L,), jnp.float32),
                 ("hops_op", (W,), jnp.float32),
                 ("ops_c", (C,), jnp.float32), ("acc_c", (C,), jnp.float32),
                 ("distinct_c", (C,), jnp.float32),
                 ("mcq", (n_mcs,), jnp.float32)]

    in_names = [n for n, _ in ins]
    out_names = [n for n, _, _ in outs]

    def kernel(*refs):
        v = {n: r[...] for n, r in zip(in_names, refs[:len(in_names)])}
        o: dict[str, jnp.ndarray] = {}
        if run_shared:
            sp = ref.shared_stage(
                v["dest"], v["src1"], v["src2"], v["valid"],
                v["epochs"][0], v["rb_stamp"], v.get("page_ema"),
                v["n_pages"][0] if pei else None,
                v["pei_idx"][0] if pei else None, pei_k=pei_k, aimm=aimm)
            o["rb_stamp"], o["rb_winner"] = sp.rb_stamp, sp.rb_winner
            if pei:
                o["page_ema"] = sp.page_ema
                o["pei_hot1"], o["pei_hot2"] = sp.pei_hot1, sp.pei_hot2
            if aimm:
                o["touch_cnt"] = sp.touch_cnt
            winner, hot1, hot2 = sp.rb_winner, sp.pei_hot1, sp.pei_hot2
        else:
            winner = v.get("rb_winner")
            hot1, hot2 = v.get("pei_hot1"), v.get("pei_hot2")
        if run_route:
            rp = ref.route_stage_onehot(
                v["dest"], v["src1"], v["src2"], v["valid"], winner, hot1,
                hot2, v["eff_table"], v.get("compute_remap"),
                v["technique"][0], v["is_aimm"][0] if aimm else None,
                v["pending_mig_loads"], v["routes_flat"], v["hops_flat"],
                v["nearest_mc"], pei=pei, aimm=aimm, n_mcs=n_mcs,
                packet_flits=packet_flits)
            for name, val in zip(RouteParts._fields, rp):
                o[name] = val
        for n, r in zip(out_names, refs[len(in_names):]):
            r[...] = o[n]

    res = pl.pallas_call(
        kernel,
        out_shape=tuple(jax.ShapeDtypeStruct(s, d) for _, s, d in outs),
        interpret=interpret,
    )(*[a for _, a in ins])
    by_name = dict(zip(out_names, res))

    sparts = rparts = None
    if run_shared:
        sparts = SharedParts(
            rb_stamp=by_name["rb_stamp"], rb_winner=by_name["rb_winner"],
            page_ema=by_name.get("page_ema"),
            pei_hot1=by_name.get("pei_hot1"),
            pei_hot2=by_name.get("pei_hot2"),
            touch_cnt=by_name.get("touch_cnt"))
    if run_route:
        rparts = RouteParts(**{n: by_name[n] for n in RouteParts._fields})
    return sparts, rparts


def tom_scores_call(dest, src1, src2, valid, cands, *, n_cubes: int,
                    interpret: bool = True) -> jnp.ndarray:
    """(K,) TOM candidate scores for one lane's window, as a Pallas call."""
    K = cands.shape[0]

    def kernel(dest_ref, s1_ref, s2_ref, v_ref, c_ref, out_ref):
        out_ref[...] = ref.tom_stage_loop(
            dest_ref[...], s1_ref[...], s2_ref[...], v_ref[...], c_ref[...],
            n_cubes)

    return pl.pallas_call(
        kernel, out_shape=jax.ShapeDtypeStruct((K,), jnp.float32),
        interpret=interpret,
    )(dest, src1, src2, valid, cands)
