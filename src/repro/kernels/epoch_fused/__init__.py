"""Fused Pallas epoch kernel: the NMP epoch simulation core (row-buffer
stamp-and-count, PEI thresholding, EMA update, schedule/route/count) as one
kernel, selected via REPRO_EPOCH_BACKEND.  See ops.py for the dispatch
contract and kernel.py for the Pallas entry points."""
from repro.kernels.epoch_fused.ops import (EPOCH_BACKENDS,  # noqa: F401
                                           resolve_backend)
