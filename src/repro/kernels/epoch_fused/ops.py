"""Backend dispatch for the fused epoch core.

`REPRO_EPOCH_BACKEND` selects how the epoch simulation core executes:

  auto             pallas on TPU, jnp elsewhere (the default)
  jnp              the historical gather/einsum path (bit-exact reference)
  pallas           the fused kernel (interpret-mode off-TPU, so it runs —
                   and stays bit-identical — on any backend)
  pallas_interpret the fused kernel forced into interpreter mode everywhere
                   (the CI parity lane)

The knob is validated eagerly at import AND at every resolve, raising a
ValueError that names the knob and the offending value (same contract as
`REPRO_QNET_BACKEND` in repro.core.dqn).  The resolved backend is carried
in `engine.BodyFlags.epoch_backend` — a static jit argument — so flipping
the env var between calls selects a distinct compiled program instead of
being silently frozen into a resident one.

Dispatchers below take the same arrays for every backend and return the
stage NamedTuples from `ref`; the topology object is passed opaquely (duck
typed) so this package never imports `repro.nmp.topology`.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.epoch_fused import kernel, ref
from repro.kernels.epoch_fused.ref import RouteParts, SharedParts

ENV_KNOB = "REPRO_EPOCH_BACKEND"
EPOCH_BACKENDS = ("auto", "jnp", "pallas", "pallas_interpret")


def _validate_backend(mode: str, source: str) -> str:
    if mode not in EPOCH_BACKENDS:
        raise ValueError(
            f"{source}={mode!r} is not a valid epoch backend; expected one "
            f"of {EPOCH_BACKENDS} (auto = pallas on TPU / jnp elsewhere; "
            f"pallas_interpret forces the kernel's interpreter mode on any "
            f"backend)")
    return mode


# Fail fast on a typo'd env knob: at import, not at first dispatch.
_validate_backend(os.environ.get(ENV_KNOB, "auto"), ENV_KNOB)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(mode: str | None = None) -> str:
    """Resolve the requested mode (default: the env knob) to one of
    {jnp, pallas, pallas_interpret}; validates either source."""
    if mode is None:
        mode = _validate_backend(os.environ.get(ENV_KNOB, "auto"), ENV_KNOB)
    else:
        _validate_backend(mode, "epoch backend")
    if mode == "auto":
        return "pallas" if _on_tpu() else "jnp"
    return mode


def _interpret(backend: str) -> bool:
    # `pallas` off-TPU still runs (and tests) the kernel via interpret mode.
    return backend == "pallas_interpret" or not _on_tpu()


def shared_parts(dest, src1, src2, valid, epochs, rb_stamp, page_ema,
                 n_pages, pei_idx, *, pei_k: int, aimm: bool,
                 backend: str) -> SharedParts:
    """Seed-invariant stage for one lane (engine `_shared_epoch` core)."""
    if backend == "jnp":
        return ref.shared_stage(dest, src1, src2, valid, epochs, rb_stamp,
                                page_ema if pei_k > 0 else None,
                                n_pages if pei_k > 0 else None,
                                pei_idx if pei_k > 0 else None,
                                pei_k=pei_k, aimm=aimm)
    sp, _ = kernel.fused_epoch_call(
        dest, src1, src2, valid, epochs=epochs, rb_stamp=rb_stamp,
        page_ema=page_ema if pei_k > 0 else None, n_pages=n_pages,
        pei_idx=pei_idx, pei_k=pei_k, aimm=aimm, run_shared=True,
        run_route=False, interpret=_interpret(backend))
    return sp


def route_parts(dest, src1, src2, valid, rb_winner, pei_hot1, pei_hot2,
                eff_table, compute_remap, technique, is_aimm,
                pending_mig_loads, topo, *, pei_k: int, aimm: bool,
                n_mcs: int, packet_flits: float, backend: str) -> RouteParts:
    """Schedule/route/count stage for one cell (`_epoch_sim` route core)."""
    if backend == "jnp":
        return ref.route_stage(
            dest, src1, src2, valid, rb_winner, pei_hot1, pei_hot2,
            eff_table, compute_remap, technique, is_aimm, pending_mig_loads,
            jnp.asarray(topo.route_links), jnp.asarray(topo.hops),
            jnp.asarray(topo.nearest_mc), pei=pei_k > 0, aimm=aimm,
            n_mcs=n_mcs, packet_flits=packet_flits)
    _, rp = kernel.fused_epoch_call(
        dest, src1, src2, valid, rb_winner=rb_winner, pei_hot1=pei_hot1,
        pei_hot2=pei_hot2, eff_table=eff_table, compute_remap=compute_remap,
        technique=technique, is_aimm=is_aimm,
        pending_mig_loads=pending_mig_loads,
        routes_flat=jnp.asarray(topo.routes_flat),
        hops_flat=jnp.asarray(topo.hops_flat),
        nearest_mc=jnp.asarray(topo.nearest_mc), pei_k=pei_k, aimm=aimm,
        run_shared=False, run_route=True, n_mcs=n_mcs,
        packet_flits=packet_flits, interpret=_interpret(backend))
    return rp


def fused_parts(dest, src1, src2, valid, epochs, rb_stamp, page_ema,
                n_pages, pei_idx, eff_table, compute_remap, technique,
                is_aimm, pending_mig_loads, topo, *, pei_k: int, aimm: bool,
                n_mcs: int, packet_flits: float, backend: str
                ) -> tuple[SharedParts, RouteParts]:
    """Both stages in ONE kernel launch — the fully-fused per-cell path used
    when the epoch driver is not seed-sharing.  (The jnp backend never calls
    this; it runs the two ref stages inline via the dispatchers above.)"""
    assert backend != "jnp"
    sp, rp = kernel.fused_epoch_call(
        dest, src1, src2, valid, epochs=epochs, rb_stamp=rb_stamp,
        page_ema=page_ema if pei_k > 0 else None, n_pages=n_pages,
        pei_idx=pei_idx, eff_table=eff_table, compute_remap=compute_remap,
        technique=technique, is_aimm=is_aimm,
        pending_mig_loads=pending_mig_loads,
        routes_flat=jnp.asarray(topo.routes_flat),
        hops_flat=jnp.asarray(topo.hops_flat),
        nearest_mc=jnp.asarray(topo.nearest_mc), pei_k=pei_k, aimm=aimm,
        run_shared=True, run_route=True, n_mcs=n_mcs,
        packet_flits=packet_flits, interpret=_interpret(backend))
    return sp, rp


def tom_scores(dest, src1, src2, valid, cands, n_cubes: int, *,
               backend: str) -> jnp.ndarray:
    """(K,) TOM candidate scores for one lane's window."""
    if backend == "jnp":
        return ref.tom_stage(dest, src1, src2, valid, cands, n_cubes)
    return kernel.tom_scores_call(dest, src1, src2, valid, cands,
                                  n_cubes=n_cubes,
                                  interpret=_interpret(backend))
