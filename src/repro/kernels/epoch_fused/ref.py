"""Single-source stage math for the fused epoch core.

The epoch hot path (engine `_shared_epoch` + the schedule/route/count block
of `_epoch_sim`) is split here into three pure stage functions so the jnp
dispatch path and the Pallas kernel body execute the *same code*:

  shared_stage : row-buffer stamp-and-count, PEI top_k threshold + hot
                 flags, access-EMA decay/update, page touch counts — the
                 seed-invariant half of the cost model.
  route_stage  : effective-table gathers, technique scheduling (incl. PEI
                 hot-source placement and the AIMM compute-remap override),
                 per-link flit loads, hop counts, per-cube compute /
                 access / row-buffer-distinct counts and MC-queue depths.
  tom_stage    : TOM candidate co-location scores for one op window.

`route_stage` comes in two flavors that are exactly equal in value and in
bits: the gather/einsum form (the historical engine inline code, used by the
jnp backend) and a one-hot matmul form (used inside the kernel body, where
pair-indexed matmuls against the topology's `routes_flat`/`hops_flat`
layouts map onto the MXU).  Exactness contract: every weight entering a
reduction is an exact small integer (0/1 route incidence, 0/1 validity,
integer hop counts) or an exact small-integer multiple of `packet_flits`,
and all sums stay far below 2**24 — so scatter-adds, einsums and one-hot
matmuls produce identical f32 bits under ANY reduction order.  The engine
goldens (tests/test_engine_golden.py) and the parity suite
(tests/test_pallas_parity.py) pin this.

Layering note: this module imports `repro.nmp.baselines` (technique
scheduling + TOM scoring) — the epoch kernel *is* the NMP epoch core, so
unlike `dueling_qnet` it is not model-agnostic.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.nmp.baselines import (TECHNIQUES, schedule_by_id,
                                 tom_colocation_score)

LDB_ID = TECHNIQUES.index("ldb")


class SharedParts(NamedTuple):
    """Outputs of the seed-invariant stage (see engine.SharedEpoch)."""
    rb_stamp: jnp.ndarray           # (P+1,) i32 updated row-buffer stamps
    rb_winner: jnp.ndarray          # (3W,) bool first-touch indicators
    page_ema: jnp.ndarray | None    # (P,) f32 updated access EMA (PEI only)
    pei_hot1: jnp.ndarray | None    # (W,) bool src1 above PEI threshold
    pei_hot2: jnp.ndarray | None    # (W,) bool
    touch_cnt: jnp.ndarray | None   # (P,) f32 window touch counts (AIMM)


class RouteParts(NamedTuple):
    """Outputs of the schedule/route/count stage of `_epoch_sim`."""
    ccube: jnp.ndarray      # (W,) i32 scheduled compute cube per op
    loads: jnp.ndarray      # (L,) f32 per-link flit loads (+ pending mig)
    hops_op: jnp.ndarray    # (W,) f32 total hops per op
    ops_c: jnp.ndarray      # (C,) f32 compute ops per cube
    acc_c: jnp.ndarray      # (C,) f32 accesses per cube
    distinct_c: jnp.ndarray  # (C,) f32 distinct pages touched per cube
    mcq: jnp.ndarray        # (M,) f32 MC queue depths


def shared_stage(dest, src1, src2, valid, epochs, rb_stamp, page_ema,
                 n_pages, pei_idx, *, pei_k: int, aimm: bool) -> SharedParts:
    """Seed-invariant epoch quantities — bit-identical to the historical
    inline computation in `engine._shared_epoch`."""
    P = rb_stamp.shape[0] - 1
    W = dest.shape[0]

    # Row-buffer stamp race: pages are stamped (not cubes), so winners are
    # mapping-independent even though the per-cube distinct counts are not.
    acc_page = jnp.concatenate([dest, src1, src2])
    acc_valid = jnp.concatenate([valid, valid, valid])
    tag_base = (epochs.astype(jnp.int32) + 1) * (3 * W)
    stamp_val = jnp.where(acc_valid > 0,
                          tag_base + jnp.arange(3 * W, dtype=jnp.int32), 0)
    stamp_idx = jnp.where(acc_valid > 0, acc_page, jnp.int32(P))
    new_stamp = rb_stamp.at[stamp_idx].max(stamp_val)
    rb_winner = (new_stamp[stamp_idx] == stamp_val) & (acc_valid > 0)

    if pei_k > 0:
        # PEI hot threshold = the m-th largest access EMA among the real
        # pages, read from a static top_k envelope (see engine module doc).
        # Thresholds read the PRE-update EMA; the decayed EMA is stored.
        top = jax.lax.top_k(page_ema, pei_k)[0]
        m = n_pages - pei_idx
        thresh = top[jnp.clip(m - 1, 0, pei_k - 1)]
        pei_hot1 = page_ema[src1] >= jnp.maximum(thresh, 1e-6)
        pei_hot2 = page_ema[src2] >= jnp.maximum(thresh, 1e-6)
        new_ema = 0.9 * page_ema
        new_ema = new_ema.at[dest].add(valid).at[src1].add(
            valid).at[src2].add(valid)
    else:
        pei_hot1 = pei_hot2 = new_ema = None

    touch_cnt = (jnp.zeros((P,)).at[acc_page].add(acc_valid)
                 if aimm else None)
    return SharedParts(rb_stamp=new_stamp, rb_winner=rb_winner,
                       page_ema=new_ema, pei_hot1=pei_hot1,
                       pei_hot2=pei_hot2, touch_cnt=touch_cnt)


def _compute_cubes(dest, src1, src2, eff_table, compute_remap, technique,
                   is_aimm, pei_hot1, pei_hot2, n_cubes, *, pei: bool,
                   aimm: bool):
    """Schedule the compute cube per op: technique baseline + AIMM remap."""
    dcube = eff_table[dest]
    s1cube = eff_table[src1]
    s2cube = eff_table[src2]
    if pei:
        ccube = schedule_by_id(technique, dcube, s1cube, s2cube,
                               pei_hot1, pei_hot2)
    else:
        # No PEI lane in this program: schedule_by_id collapses to LDB/BNMP.
        ccube = jnp.where(technique == LDB_ID, s1cube, dcube)
    if aimm:
        # compute-remap table: -1 none, 0..C-1 fixed cube, C = "source mode"
        cr = compute_remap[dest]
        cr = jnp.where(cr >= 0, cr, compute_remap[src1])
        cr = jnp.where(cr >= 0, cr, compute_remap[src2])
        aimm_cc = jnp.where(cr == n_cubes, s1cube,
                            jnp.where(cr >= 0, cr, ccube))
        ccube = jnp.where(is_aimm, aimm_cc, ccube)
    return dcube, s1cube, s2cube, ccube


def route_stage(dest, src1, src2, valid, rb_winner, pei_hot1, pei_hot2,
                eff_table, compute_remap, technique, is_aimm,
                pending_mig_loads, route_links, hops, nearest_mc, *,
                pei: bool, aimm: bool, n_mcs: int,
                packet_flits: float) -> RouteParts:
    """Gather/einsum flavor — the historical engine inline code, verbatim."""
    C = route_links.shape[0]
    dcube, s1cube, s2cube, ccube = _compute_cubes(
        dest, src1, src2, eff_table, compute_remap, technique, is_aimm,
        pei_hot1, pei_hot2, C, pei=pei, aimm=aimm)

    # flows s1->c, s2->c, c->d (zero-hop flows drop out implicitly)
    fsrc = jnp.concatenate([s1cube, s2cube, ccube])
    fdst = jnp.concatenate([ccube, ccube, dcube])
    fw = jnp.concatenate([valid, valid, valid]) * packet_flits
    routes = route_links[fsrc, fdst]                           # (3W, L)
    loads = (jnp.einsum("f,fl->l", fw.astype(jnp.float32), routes)
             + pending_mig_loads)

    hops_op = (hops[s1cube, ccube] + hops[s2cube, ccube]
               + hops[ccube, dcube]).astype(jnp.float32)

    ops_c = jnp.zeros((C,)).at[ccube].add(valid)
    acc_cube = jnp.concatenate([dcube, s1cube, s2cube])
    acc_valid = jnp.concatenate([valid, valid, valid])
    distinct_c = jnp.zeros((C,)).at[acc_cube].add(
        rb_winner.astype(jnp.float32))
    acc_c = jnp.zeros((C,)).at[acc_cube].add(acc_valid)
    mcq = jnp.zeros((n_mcs,)).at[nearest_mc[dcube]].add(valid)
    return RouteParts(ccube=ccube, loads=loads, hops_op=hops_op, ops_c=ops_c,
                      acc_c=acc_c, distinct_c=distinct_c, mcq=mcq)


def _onehot(idx, n):
    """(len(idx), n) f32 one-hot rows via broadcasted_iota (TPU-safe)."""
    iota = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], n), 1)
    return (idx[:, None] == iota).astype(jnp.float32)


def _dot(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def route_stage_onehot(dest, src1, src2, valid, rb_winner, pei_hot1,
                       pei_hot2, eff_table, compute_remap, technique,
                       is_aimm, pending_mig_loads, routes_flat, hops_flat,
                       nearest_mc, *, pei: bool, aimm: bool, n_mcs: int,
                       packet_flits: float) -> RouteParts:
    """One-hot matmul flavor of `route_stage` for the kernel body: every
    C- and (C*C)-indexed gather/scatter becomes a one-hot matmul against
    the topology's pair-flattened tensors.  Bit-identical to the gather
    flavor (each one-hot row selects exactly one table row; every reduction
    sums exact small integers — see module doc)."""
    C = nearest_mc.shape[0]
    dcube, s1cube, s2cube, ccube = _compute_cubes(
        dest, src1, src2, eff_table, compute_remap, technique, is_aimm,
        pei_hot1, pei_hot2, C, pei=pei, aimm=aimm)

    fsrc = jnp.concatenate([s1cube, s2cube, ccube])
    fdst = jnp.concatenate([ccube, ccube, dcube])
    fw = jnp.concatenate([valid, valid, valid]) * packet_flits
    routes = _dot(_onehot(fsrc * C + fdst, C * C), routes_flat)  # (3W, L)
    loads = _dot(fw.astype(jnp.float32), routes) + pending_mig_loads

    hops_op = (_dot(_onehot(s1cube * C + ccube, C * C), hops_flat)
               + _dot(_onehot(s2cube * C + ccube, C * C), hops_flat)
               + _dot(_onehot(ccube * C + dcube, C * C), hops_flat))

    ops_c = _dot(valid, _onehot(ccube, C))
    acc_cube = jnp.concatenate([dcube, s1cube, s2cube])
    acc_valid = jnp.concatenate([valid, valid, valid])
    acc_oh = _onehot(acc_cube, C)                                # (3W, C)
    distinct_c = _dot(rb_winner.astype(jnp.float32), acc_oh)
    acc_c = _dot(acc_valid, acc_oh)
    mc_oh = (nearest_mc[:, None]
             == jax.lax.broadcasted_iota(jnp.int32, (C, n_mcs), 1)
             ).astype(jnp.float32)                               # (C, M)
    mcq = _dot(valid, _dot(_onehot(dcube, C), mc_oh))
    return RouteParts(ccube=ccube, loads=loads, hops_op=hops_op, ops_c=ops_c,
                      acc_c=acc_c, distinct_c=distinct_c, mcq=mcq)


def tom_stage(dest, src1, src2, valid, cands, n_cubes: int) -> jnp.ndarray:
    """(K,) TOM candidate co-location scores — vmap flavor (the historical
    `engine._tom_window_scores` body, used by the jnp backend)."""
    def score_k(k):
        return tom_colocation_score(cands[k], dest, src1, src2, valid,
                                    n_cubes)
    return jax.vmap(score_k)(jnp.arange(cands.shape[0]))


def tom_stage_loop(dest, src1, src2, valid, cands, n_cubes: int
                   ) -> jnp.ndarray:
    """Unrolled flavor for the kernel body (K is a static constant; a Python
    loop avoids vmap-inside-kernel).  Same math per candidate."""
    return jnp.stack([
        tom_colocation_score(cands[k], dest, src1, src2, valid, n_cubes)
        for k in range(cands.shape[0])])
