"""Pallas TPU kernel: Mamba2 SSD chunked scan.

Grid = (batch, n_chunks): the chunk axis iterates sequentially ('arbitrary')
carrying the inter-chunk state R (H, N, P) in VMEM scratch — the recurrence
never round-trips HBM. Each grid step computes, for one (batch, chunk):

  seg      = cumsum(dt * A) within the chunk                (Q, H)
  intra    : (C B^T ⊙ decay ⊙ dt) X  via two MXU contractions per head block
  inter    : C · R ⊙ exp(seg)
  state    : R <- exp(seg_end) R + sum_j exp(seg_end - seg_j) B_j (dt_j X_j)

The per-head decay tensor lives only at (Q, Q, Hb) block granularity in VMEM
(head-blocked to bound the working set); Q=chunk and head_block are chosen so
Q*Q*Hb*4B stays << VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 exposes TPU compiler options as TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, a_ref, y_ref, r_scr, *,
                chunk: int, n_heads: int, d_state: int, head_dim: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        r_scr[...] = jnp.zeros_like(r_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, H, P)
    B = b_ref[0].astype(jnp.float32)          # (Q, N)
    C = c_ref[0].astype(jnp.float32)          # (Q, N)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, H)
    A = a_ref[...].astype(jnp.float32)        # (H,)

    dA = dt * A                               # (Q, H)
    seg = jnp.cumsum(dA, axis=0)
    seg_end = seg[-1:]                        # (1, H)

    CB = jnp.dot(C, B.T, preferred_element_type=jnp.float32)   # (Q, Q)
    Q = chunk
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tril = qi >= kj

    # decay (Q, Q, H) = exp(seg_i - seg_j); built per full head dim here —
    # head-blocking happens at the pallas grid level via vmap on H groups in
    # ops.py when H*Q*Q*4B would exceed VMEM.
    decay = jnp.exp(jnp.clip(seg[:, None, :] - seg[None, :, :], -60.0, 0.0))
    att = CB[:, :, None] * decay * jnp.where(tril[:, :, None], 1.0, 0.0)
    att = att * dt[None, :, :]                                  # weight dt_j
    y_intra = jnp.einsum("ijh,jhp->ihp", att, x)

    R = r_scr[...]                                              # (H, N, P)
    in_decay = jnp.exp(jnp.clip(seg, -60.0, 0.0))               # (Q, H)
    y_inter = jnp.einsum("in,ih,hnp->ihp", C, in_decay, R)

    state_w = jnp.exp(jnp.clip(seg_end - seg, -60.0, 0.0)) * dt  # (Q, H)
    S_new = jnp.einsum("jn,jh,jhp->hnp", B, state_w, x)
    r_scr[...] = R * jnp.exp(jnp.clip(seg_end[0], -60.0, 0.0))[:, None, None] \
        + S_new

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, b, c, dt, a, *, chunk: int = 128, interpret: bool = False):
    """x: (B, L, H, P); b,c: (B, L, N); dt: (B, L, H); a: (H,) (negative).

    Returns y: (B, L, H, P). L % chunk == 0.
    """
    Bsz, L, H, P = x.shape
    N = b.shape[-1]
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    grid = (Bsz, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_heads=H,
                               d_state=N, head_dim=P)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda bi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, chunk, N), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, H), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((H,), lambda bi, ci: (0,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, H, P), lambda bi, ci: (bi, ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, L, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((H, N, P), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(x, b, c, dt, a)
