"""Pure-jnp oracle for the SSD scan: sequential state-space recurrence.

y_t = C_t . S_t + 0   with  S_t = exp(dt_t * A) S_{t-1} + B_t (x) (dt_t x_t)

(The D-skip and gating live outside the kernel in the model layer.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, b, c, dt, a):
    """x: (B, L, H, P); b,c: (B, L, N); dt: (B, L, H); a: (H,) negative.
    Returns (B, L, H, P), fp32."""
    Bsz, L, H, P = x.shape
    N = b.shape[-1]
    x = x.astype(jnp.float32)
    b = b.astype(jnp.float32)
    c = c.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    a = a.astype(jnp.float32)

    def step(S, inp):
        x_t, b_t, c_t, dt_t = inp           # (B,H,P) (B,N) (B,N) (B,H)
        decay = jnp.exp(dt_t * a)           # (B,H)
        S = S * decay[:, :, None, None] + jnp.einsum(
            "bn,bh,bhp->bhnp", b_t, dt_t, x_t)
        y = jnp.einsum("bn,bhnp->bhp", c_t, S)
        return S, y

    S0 = jnp.zeros((Bsz, H, N, P))
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(b, 1, 0),
          jnp.moveaxis(c, 1, 0), jnp.moveaxis(dt, 1, 0))
    _, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1)           # (B, L, H, P)
