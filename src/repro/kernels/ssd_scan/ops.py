"""Jitted wrapper for the SSD Pallas kernel (interpret on CPU), with
head-group splitting when the (Q, Q, H) decay block would exceed VMEM."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan

VMEM_BUDGET = 8 * 2 ** 20       # conservative half-VMEM working-set target


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssd(x, b, c, dt, a, *, chunk: int = 128,
        interpret: bool | None = None):
    """x: (B, L, H, P); b,c: (B, L, N); dt: (B, L, H); a: (H,)."""
    if interpret is None:
        interpret = not _on_tpu()
    Bsz, L, H, P = x.shape
    # head-group split so chunk*chunk*Hg*4B fits the VMEM budget
    hg = max(int(VMEM_BUDGET // (chunk * chunk * 4)), 1)
    hg = min(hg, H)
    while H % hg:
        hg -= 1
    if hg == H:
        return ssd_scan(x, b, c, dt, a, chunk=chunk, interpret=interpret)
    groups = H // hg
    xg = x.reshape(Bsz, L, groups, hg, P)
    dtg = dt.reshape(Bsz, L, groups, hg)
    ag = a.reshape(groups, hg)

    def one(g):
        return ssd_scan(xg[:, :, g], b, c, dtg[:, :, g], ag[g], chunk=chunk,
                        interpret=interpret)

    ys = jax.lax.map(one, jnp.arange(groups))       # (G, B, L, hg, P)
    return jnp.moveaxis(ys, 0, 2).reshape(Bsz, L, H, P)
