"""Pallas TPU kernel: blocked causal GQA flash attention (prefill hot-spot).

Grid = (batch*q_heads, S/BLOCK_Q, S/BLOCK_KV); the last axis iterates
sequentially ('arbitrary' semantics) carrying the online-softmax state
(m, l, acc) in VMEM scratch. Causal skipping: KV blocks strictly above the
diagonal write nothing (pl.when guard), so wasted MXU work is at most the
diagonal block — unlike the XLA-scan fallback which computes the full S^2.

Block sizes default to 128/256: q/k tiles of (128, head_dim) with
head_dim in {64,128,256} keep the MXU's 128x128 systolic array fed while the
per-step working set (q tile + kv tile + logits tile ~ 128*256*4B) stays well
under VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 exposes TPU compiler options as TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

BLOCK_Q = 128
BLOCK_KV = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_kv: int, causal: bool):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = (not causal) or (kj * block_kv <= (qi + 1) * block_q - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                    # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                    # (bkv, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0)
            kpos = kj * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * corr
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("scale", "causal", "block_q", "block_kv",
                                    "interpret"))
def flash_attention(q, k, v, *, scale: float | None = None,
                    causal: bool = True, block_q: int = BLOCK_Q,
                    block_kv: int = BLOCK_KV, interpret: bool = False):
    """q: (B, H, S, hd); k/v: (B, H, S, hd) (kv already GQA-expanded or H==K).

    Returns (B, H, S, hd).
    """
    B, H, S, hd = q.shape
    assert S % block_q == 0 and S % block_kv == 0, (S, block_q, block_kv)
    scale = hd ** -0.5 if scale is None else scale
    qf = q.reshape(B * H, S, hd)
    kf = k.reshape(B * H, S, hd)
    vf = v.reshape(B * H, S, hd)
    grid = (B * H, S // block_q, S // block_kv)
    kernel = functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                               block_kv=block_kv, causal=causal)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd)
