"""Pure-jnp oracle: dense (masked) softmax attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, scale: float | None = None, causal: bool = True):
    """q,k,v: (B, H, S, hd) -> (B, H, S, hd), fp32 math."""
    B, H, S, hd = q.shape
    scale = hd ** -0.5 if scale is None else scale
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
