"""Jitted wrapper: GQA expansion + layout (B,S,H,hd)<->(B,H,S,hd) + padding,
dispatching to the Pallas flash kernel (interpret on CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (BLOCK_KV, BLOCK_Q,
                                                  flash_attention)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gqa_flash_attention(q, k, v, *, causal: bool = True,
                        scale: float | None = None,
                        interpret: bool | None = None):
    """q: (B, S, H, hd); k/v: (B, S, K, hd) with H % K == 0.

    Returns (B, S, H, hd)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, S, H, hd = q.shape
    K = k.shape[2]
    rep = H // K
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    bq = min(BLOCK_Q, S)
    bkv = min(BLOCK_KV, S)
    pad = (-S) % max(bq, bkv)
    # zero-padded KV rows are masked out by causality; for bidirectional
    # attention the caller must supply block-aligned S
    assert causal or pad == 0, "non-causal requires block-aligned seq len"
    if pad:
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        qp, kp, vp = q, k, v
    out = flash_attention(qp.transpose(0, 2, 1, 3), kp.transpose(0, 2, 1, 3),
                          vp.transpose(0, 2, 1, 3), causal=causal,
                          scale=scale, block_q=bq, block_kv=bkv,
                          interpret=interpret)
    return out.transpose(0, 2, 1, 3)[:, :S]
