"""Kernel micro-benchmarks: wall time of the jnp reference vs the Pallas
kernel in interpret mode is NOT meaningful on CPU; this bench reports
reference-path timings (the oracle is the deployable CPU path) plus
correctness deltas, and serves as the harness that would time the compiled
kernels on TPU."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, emit
from repro.kernels.dueling_qnet.ref import dueling_qnet_ref
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ref import ssd_ref


def _time(f, *args, iters=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run():
    r = np.random.default_rng(0)
    # qnet: replay-batch inference
    S, H, A, B = 128, 128, 8, 256
    params = [jnp.asarray(r.standard_normal(s).astype(np.float32)) * 0.2
              for s in ((S, H), (H,), (H, H), (H,), (H, 1), (1,), (H, A), (A,))]
    x = jnp.asarray(r.standard_normal((B, S)).astype(np.float32))
    f = jax.jit(lambda x: dueling_qnet_ref(x, *params))
    emit("kernel/dueling_qnet_ref_b256", _time(f, x), "q_inference")

    # flash attention ref at 2k
    q = jnp.asarray(r.standard_normal((1, 8, 2048, 64)).astype(np.float32))
    f = jax.jit(lambda q: attention_ref(q, q, q))
    emit("kernel/attention_ref_2k", _time(f, q), "prefill_attention")

    # ssd ref at 2k
    x = jnp.asarray(r.standard_normal((1, 2048, 8, 64)).astype(np.float32))
    b = jnp.asarray(r.standard_normal((1, 2048, 64)).astype(np.float32))
    dt = jnp.abs(jnp.asarray(r.standard_normal((1, 2048, 8)).astype(np.float32))) * .1
    a = -jnp.abs(jnp.asarray(r.standard_normal(8).astype(np.float32))) - .1
    f = jax.jit(lambda x, b, dt, a: ssd_ref(x, b, b, dt, a))
    emit("kernel/ssd_ref_2k", _time(f, x, b, dt, a), "ssd_scan")


if __name__ == "__main__":
    run()
