"""Fig. 12: multi-program workloads (2/3/4 apps) — BNMP shared baseline vs
BNMP+HOARD vs BNMP+HOARD+AIMM (paper: HOARD and AIMM complement each other)."""
import time

from benchmarks.common import EPISODES, FULL, N_OPS, Timer, emit
from repro.nmp import NMPConfig, make_trace, merge_traces, run_episode, \
    run_program
from repro.nmp.paging import hoard_alloc
from repro.nmp.stats import summarize
from repro.nmp.traces import program_of_page

COMBOS = [
    ("SC-KM", ("SC", "KM")),
    ("LUD-RBM-SPMV", ("LUD", "RBM", "SPMV")),
    ("SC-KM-RD-MAC", ("SC", "KM", "RD", "MAC")),
]


def run():
    cfg = NMPConfig()
    per = max(N_OPS // 2, 4096)
    for name, combo in COMBOS:
        tr = merge_traces([make_trace(a, n_ops=per) for a in combo])
        with Timer() as t0:
            base = run_episode(tr, cfg, "bnmp", "none")
        bcyc = summarize(base)["cycles"]
        emit(f"fig12/{name}/BNMP", t0.us, 1.0)

        hoard_table = hoard_alloc(tr.n_pages, cfg, program_of_page(tr))
        with Timer() as t1:
            h = run_episode(tr, cfg, "bnmp", "none", page_table=hoard_table)
        emit(f"fig12/{name}/BNMP+HOARD", t1.us,
             round(summarize(h)["cycles"] / bcyc, 4))

        with Timer() as t2:
            results = run_program(tr, cfg, "bnmp", "aimm",
                                  episodes=max(EPISODES, 3), seed=0,
                                  page_table=hoard_table)
        emit(f"fig12/{name}/BNMP+HOARD+AIMM", t2.us,
             round(summarize(results[-1])["cycles"] / bcyc, 4))


if __name__ == "__main__":
    run()
