"""Fig. 12: multi-program workloads (2/3/4 apps) — BNMP shared baseline vs
BNMP+HOARD vs BNMP+HOARD+AIMM (paper: HOARD and AIMM complement each other).

The three lanes of every combo run through the batched sweep engine: one
`scenarios.multi_program_grid` -> `sweep.run_grid` call (memoized in
common.cached_grid) covers the whole figure instead of one simulator
invocation per (combo, allocator, mapper).
"""
from benchmarks.common import EPISODES, N_OPS, cached_grid, emit, lane_summary
from repro.nmp.scenarios import DEFAULT_COMBOS


def run():
    per = max(N_OPS // 2, 4096)
    cached = cached_grid("multi", combos=DEFAULT_COMBOS, n_ops_per_app=per,
                         aimm_episodes=max(EPISODES, 3))
    us = cached["us"] / len(cached["grid"])

    for combo, _ in DEFAULT_COMBOS:
        base = lane_summary(cached, f"{combo}/shared/s0")["cycles"]
        emit(f"fig12/{combo}/BNMP", us, 1.0)
        hoard = lane_summary(cached, f"{combo}/hoard/s0")["cycles"]
        emit(f"fig12/{combo}/BNMP+HOARD", us, round(hoard / base, 4))
        aimm = lane_summary(cached, f"{combo}/hoard+aimm/s0")["cycles"]
        emit(f"fig12/{combo}/BNMP+HOARD+AIMM", us, round(aimm / base, 4))


if __name__ == "__main__":
    run()
