"""Fig. 5: workload analysis — page-access classes, active pages, affinity.

Plus the sweep-engine benchmark: the same app x mapper x seed grid run (a)
through the batched `sweep.run_grid` (one compile + one dispatch per agent
mode) and (b) through the serial per-cell loop, with wall-clock for both and
their speedup. The per-lane metrics are asserted identical, so the speedup
row is an apples-to-apples compile/dispatch amortization measurement.
"""
from benchmarks.common import FULL, N_OPS, Timer, emit
from repro.nmp.traces import APPS, analyze, make_trace


def run():
    for app in APPS:
        with Timer() as t:
            tr = make_trace(app, n_ops=N_OPS)
            a = analyze(tr)
        emit(f"fig5/{app}/heavy_frac", t.us, round(a["classes"]["heavy"], 4))
        emit(f"fig5/{app}/active_pages", t.us,
             round(a["active_pages_mean"], 1))
        emit(f"fig5/{app}/radix_mean", t.us, round(a["radix_mean"], 2))
    run_sweep_comparison()


def run_sweep_comparison():
    from repro.nmp.scenarios import single_program_grid
    from repro.nmp.sweep import run_grid, run_grid_serial

    n_ops = N_OPS // 2 if FULL else N_OPS // 8
    grid = single_program_grid(
        apps=("KM", "PR", "SPMV"), mappers=("none", "tom", "aimm"),
        n_ops=n_ops, seeds=(0, 1), aimm_episodes=3 if FULL else 2)

    res = run_grid(grid)                      # wall_s includes build + compile
    with Timer() as t_serial:
        serial = run_grid_serial(grid)

    mismatches = sum(
        1 for i in range(len(grid))
        if serial[i]["cycles"] != res.episode_summary(i)["cycles"])
    batched_us = res.wall_s * 1e6
    emit(f"sweep/grid{len(grid)}/batched_s", batched_us,
         round(res.wall_s, 2))
    emit(f"sweep/grid{len(grid)}/serial_s", t_serial.us,
         round(t_serial.us / 1e6, 2))
    emit(f"sweep/grid{len(grid)}/speedup", batched_us,
         round(t_serial.us / batched_us, 2))
    emit(f"sweep/grid{len(grid)}/metric_mismatches", batched_us, mismatches)
    for i, sc in enumerate(grid):
        if sc.seed == 0:
            emit(f"sweep/{sc.name}/opc", batched_us / len(grid),
                 round(res.episode_summary(i)["opc"], 4))


if __name__ == "__main__":
    run()
