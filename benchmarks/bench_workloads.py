"""Fig. 5: workload analysis — page-access classes, active pages, affinity.

The sweep-engine timing rows that used to live here (batched vs serial wall
clock on the 18-lane grid) moved to bench_engine.py, which also emits the
machine-readable BENCH_engine.json perf record.
"""
from benchmarks.common import N_OPS, Timer, emit
from repro.nmp.traces import APPS, analyze, make_trace


def run():
    for app in APPS:
        with Timer() as t:
            tr = make_trace(app, n_ops=N_OPS)
            a = analyze(tr)
        emit(f"fig5/{app}/heavy_frac", t.us, round(a["classes"]["heavy"], 4))
        emit(f"fig5/{app}/active_pages", t.us,
             round(a["active_pages_mean"], 1))
        emit(f"fig5/{app}/radix_mean", t.us, round(a["radix_mean"], 2))


if __name__ == "__main__":
    run()
