"""Fused-epoch-kernel + async-pipeline benchmark: the PR 9 execute-layer
changes (fused epoch backend, async result landing, host-side agent
staging) against an emulated PR 8 configuration on the same grid.

Protocol (interleaved A/B, min of warm reps — benchmarks/common.py):

  A (PR 8 emulation): REPRO_SWEEP_LAND=sync, REPRO_STORE_STAGING=off,
     REPRO_EPOCH_BACKEND=jnp — synchronous group landing, per-cell device
     cold_start + jnp.stack agent batches, unfused jnp epoch stages.
  B (new defaults):   async landing (group k's host fetch/unfold overlaps
     group k+1's device step), preallocated numpy staging buffers with a
     cached cold-cell snapshot per (seed, agent_cfg), REPRO_EPOCH_BACKEND
     auto.

The grid is shaped to stress exactly what changed: lineage-tagged AIMM
lanes (agent staging + store write-backs on the landing path) across
several topologies plus a ragged baseline group (>= 4 compiled groups, so
async landing has device work to hide behind).  On CPU `auto` resolves the
epoch backend to the jnp path, so the A/B improvement here measures the
pipelining + staging work; the fused Pallas kernel is recorded separately
as *parity rows* (interpret-mode wall time + bit-identity vs jnp) with no
speedup claim — interpret mode is a correctness vehicle, and the Mosaic
lane is future work (ROADMAP).

Also recorded: a store-stacking microbench (`_warm_agent_batch` on a
prewarmed store, staging buffers vs historical per-cell device stacking)
and a serial spot check.  Record lands in
``bench_out/BENCH_epoch_kernel.json`` (schema: benchmarks/README.md).
"""
from __future__ import annotations

import json
import os

from benchmarks.common import (FULL, ab_compare, emit, env_overrides,
                               metrics_equal, min_warm)

JSON_PATH = os.environ.get("BENCH_EPOCH_KERNEL_JSON",
                           "bench_out/BENCH_epoch_kernel.json")

APPS = ("KM", "PR", "SPMV") if FULL else ("KM", "PR")
TOPOLOGIES = ("mesh2d", "torus2d", "ring")
SEEDS = 8 if FULL else 4
N_OPS = 1024 if FULL else 512
EPISODES = 2
REPS = 7 if FULL else 5
TARGET_IMPROVEMENT = 1.15

# PR 8 execute layer emulated on today's engine: every knob the PR 9
# execute-layer work introduced, pinned to its historical behaviour.
ENV_BASELINE = {"REPRO_SWEEP_LAND": "sync", "REPRO_STORE_STAGING": "off",
                "REPRO_EPOCH_BACKEND": "jnp"}
ENV_NEW = {"REPRO_SWEEP_LAND": None, "REPRO_STORE_STAGING": None,
           "REPRO_EPOCH_BACKEND": None}


def _grid():
    """Lineage-heavy multi-group grid: one lineage-tagged AIMM cell per
    (app, topology) with a folded seed axis, plus a ragged S=1 baseline
    group per topology.  Topology variety splits the plan into one compiled
    program per (topology, agent-mode) group — the async landing path needs
    multiple groups to overlap."""
    from repro.nmp.scenarios import Scenario, seed_variants
    from repro.nmp.traces import make_trace

    grid = []
    traces = {app: make_trace(app, n_ops=N_OPS) for app in APPS}
    for topo in TOPOLOGIES:
        for app in APPS:
            grid += seed_variants(
                Scenario(name=f"{app}/{topo}/aimm", trace=traces[app],
                         mapper="aimm", episodes=EPISODES,
                         lineage=f"{app}-{topo}", topology=topo),
                tuple(range(SEEDS)))
        grid.append(Scenario(name=f"{APPS[0]}/{topo}/none",
                             trace=traces[APPS[0]], mapper="none",
                             topology=topo))
    return grid


def run():
    from repro.nmp import NMPConfig, partition
    from repro.nmp import sweep as sweep_mod
    from repro.nmp.engine import default_agent_cfg
    from repro.nmp.sweep import run_grid, run_grid_serial

    cfg = NMPConfig()
    grid = _grid()

    # -- main A/B: PR 8 emulation vs new defaults -----------------------
    ab = ab_compare(lambda: run_grid(grid), lambda: run_grid(grid),
                    reps=REPS, env_a=ENV_BASELINE, env_b=ENV_NEW)
    res_base, res_new = ab["last_a"], ab["last_b"]
    bit_identical = metrics_equal(res_base, res_new)
    improvement = ab["improvement"]

    # serial spot check: strided subset covering both mapper kinds
    idxs = sorted(set(list(range(0, len(grid), max(1, len(grid) // 6)))[:6]
                      + [len(grid) - 1]))
    serial = run_grid_serial([grid[i] for i in idxs])
    mismatches = sum(
        1 for j, i in enumerate(idxs)
        if serial[j]["cycles"] != res_new.episode_summary(i)["cycles"])

    # -- fused-kernel parity rows (interpret mode; no speedup claim) ----
    # A small sub-grid keeps the interpret-mode emulator affordable; each
    # backend is timed resident (min-of-warm) and checked bit-identical
    # against the jnp reference path.
    sub = [sc for sc in grid if sc.topology == TOPOLOGIES[0]
           and (sc.mapper == "none" or sc.seed < 2)]
    backends = {}
    ref = None
    for backend in ("jnp", "pallas_interpret"):
        with env_overrides(REPRO_EPOCH_BACKEND=backend, **{
                k: v for k, v in ENV_NEW.items()
                if k != "REPRO_EPOCH_BACKEND"}):
            res = run_grid(sub)
            warm_s, _ = min_warm(lambda: run_grid(sub), 3)
        row = {"warm_s": round(warm_s, 4)}
        if ref is None:
            ref = res
        else:
            row["bit_identical_vs_jnp"] = metrics_equal(ref, res)
        backends[backend] = row
        emit(f"epoch_kernel/backend_{backend}/warm_s", warm_s * 1e6,
             round(warm_s, 4))

    # -- store-stacking microbench --------------------------------------
    # `_warm_agent_batch` on a prewarmed store + the largest lineage group:
    # persistent staging buffers (checkout_host + in-place rows + one
    # device transfer per leaf) vs the historical per-cell device path
    # (checkout import + jnp.stack).  Both produce bit-identical batches
    # (tests/test_pallas_parity.py); only the host cost differs.
    import jax
    store = res_new.store
    group = max((g for g in res_new.plan.groups if g.lineage),
                key=lambda g: g.n_lanes * g.n_seeds)
    agent_cfg = default_agent_cfg(cfg)
    mesh = partition.build_mesh()
    staging = sweep_mod.AgentStaging()

    def stack_staged():
        jax.block_until_ready(sweep_mod._warm_agent_batch(
            group, group.n_lanes, store, agent_cfg, mesh=mesh,
            staging=staging))

    def stack_historical():
        with env_overrides(REPRO_STORE_STAGING="off"):
            jax.block_until_ready(sweep_mod._warm_agent_batch(
                group, group.n_lanes, store, agent_cfg, mesh=mesh))

    stack_staged(); stack_historical()        # warm both paths
    staged_s, _ = min_warm(stack_staged, REPS)
    hist_s, _ = min_warm(stack_historical, REPS)
    stack_improvement = hist_s / staged_s if staged_s else float("inf")

    cells = group.n_lanes * group.n_seeds
    tag = f"epoch_kernel/cells{len(grid)}_s{SEEDS}"
    emit(f"{tag}/warm_baseline_s", ab["a_s"] * 1e6, round(ab["a_s"], 3))
    emit(f"{tag}/warm_new_s", ab["b_s"] * 1e6, round(ab["b_s"], 3))
    emit(f"{tag}/improvement_vs_pr8", ab["b_s"] * 1e6,
         round(improvement, 3))
    emit(f"{tag}/bit_identical", ab["b_s"] * 1e6, bit_identical)
    emit(f"{tag}/metric_mismatches_vs_serial", ab["b_s"] * 1e6, mismatches)
    emit(f"{tag}/stacking_improvement", staged_s * 1e6,
         round(stack_improvement, 3))

    record = {
        "grid": {"cells": len(grid), "apps": list(APPS),
                 "topologies": list(TOPOLOGIES), "seeds": SEEDS,
                 "n_ops": N_OPS, "aimm_episodes": EPISODES, "full": FULL,
                 "groups": [(g.n_lanes, g.n_seeds, g.n_episodes)
                            for g in res_new.plan.groups]},
        "mesh": partition.mesh_desc(partition.build_mesh()),
        "ab": {
            "env_baseline": ENV_BASELINE,
            "env_new": {k: "<default>" for k in ENV_NEW},
            "reps": REPS,
            "warm_baseline_s": round(ab["a_s"], 4),
            "warm_new_s": round(ab["b_s"], 4),
            "warm_baseline_all": [round(w, 4) for w in ab["a_all"]],
            "warm_new_all": [round(w, 4) for w in ab["b_all"]],
            "improvement_vs_pr8": round(improvement, 3),
            "target_improvement": TARGET_IMPROVEMENT,
            "met_target": bool(improvement >= TARGET_IMPROVEMENT),
            "bit_identical": bool(bit_identical),
        },
        "serial_spot": {"lanes_checked": len(idxs),
                        "metric_mismatches": mismatches},
        "backends": backends,
        "store_stacking": {"cells": cells,
                           "staging_s": round(staged_s, 5),
                           "historical_s": round(hist_s, 5),
                           "improvement": round(stack_improvement, 3)},
    }
    os.makedirs(os.path.dirname(JSON_PATH) or ".", exist_ok=True)
    with open(JSON_PATH, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    print(f"# wrote {JSON_PATH}", flush=True)


if __name__ == "__main__":
    run()
