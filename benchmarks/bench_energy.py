"""Fig. 14: dynamic energy — AIMM hardware vs network vs memory breakdown;
the paper's claim: AIMM-module energy is insignificant vs network energy."""
from benchmarks.common import apps, cached_episode, emit
from repro.nmp.stats import summarize


def run():
    for app in apps():
        base = summarize(cached_episode(app, "bnmp", "none")["res"])
        r = cached_episode(app, "bnmp", "aimm")
        s = summarize(r["res"])
        bd = s["energy_breakdown"]
        total = sum(bd.values())
        emit(f"fig14/{app}/aimm_hw_frac", r["us"],
             round(bd["aimm_hw"] / total, 4))
        emit(f"fig14/{app}/network_frac", r["us"],
             round(bd["network"] / total, 4))
        emit(f"fig14/{app}/memory_frac", r["us"],
             round(bd["memory"] / total, 4))
        emit(f"fig14/{app}/energy_vs_baseline", r["us"],
             round(s["energy_nj"] / max(base["energy_nj"], 1e-9), 4))


if __name__ == "__main__":
    run()
