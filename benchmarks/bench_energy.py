"""Fig. 14: dynamic energy — AIMM hardware vs network vs memory breakdown;
the paper's claim: AIMM-module energy is insignificant vs network energy.
Served from the shared batched figure grid (common.figure_grid)."""
from benchmarks.common import apps, emit, figure_grid, grid_us, lane_summary


def run():
    cached = figure_grid()
    us = grid_us(cached)
    for app in apps():
        base = lane_summary(cached, f"{app}/bnmp/none/s0")
        s = lane_summary(cached, f"{app}/bnmp/aimm/s0")
        bd = s["energy_breakdown"]
        total = sum(bd.values())
        emit(f"fig14/{app}/aimm_hw_frac", us, round(bd["aimm_hw"] / total, 4))
        emit(f"fig14/{app}/network_frac", us, round(bd["network"] / total, 4))
        emit(f"fig14/{app}/memory_frac", us, round(bd["memory"] / total, 4))
        emit(f"fig14/{app}/energy_vs_baseline", us,
             round(s["energy_nj"] / max(base["energy_nj"], 1e-9), 4))


if __name__ == "__main__":
    run()
