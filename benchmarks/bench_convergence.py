"""Fig. 9: OPC timeline (fixed-size resample, order preserved) showing the
agent converging toward higher OPC across its episodes.  The per-episode
timelines come straight out of the shared batched figure grid's stacked
metrics (continual learning across the in-scan episode chain)."""
import numpy as np

from benchmarks.common import apps, emit, figure_grid, grid_us


def run():
    cached = figure_grid()
    res, grid = cached["res"], cached["grid"]
    us = grid_us(cached)
    lanes = {sc.name: i for i, sc in enumerate(grid)}
    for app in apps():
        i = lanes[f"{app}/bnmp/aimm/s0"]
        eps = grid[i].total_episodes
        tl = np.concatenate([res.opc_timeline(i, e, samples=16)
                             for e in range(eps)])
        first, last = tl[:16].mean(), tl[-16:].mean()
        emit(f"fig9/{app}/opc_start", us, round(float(first), 4))
        emit(f"fig9/{app}/opc_end", us, round(float(last), 4))
        emit(f"fig9/{app}/convergence_gain", us,
             round(float(last / max(first, 1e-9)), 4))


if __name__ == "__main__":
    run()
