"""Fig. 9: OPC timeline (fixed-size resample, order preserved) showing the
agent converging toward higher OPC across its episodes."""
import numpy as np

from benchmarks.common import apps, cached_episode, emit
from repro.nmp.stats import opc_timeline


def run():
    for app in apps():
        r = cached_episode(app, "bnmp", "aimm")
        # concatenate episode timelines (continual learning across episodes)
        tl = np.concatenate([opc_timeline(res, samples=16)
                             for res in r["all"]])
        first, last = tl[:16].mean(), tl[-16:].mean()
        emit(f"fig9/{app}/opc_start", r["us"], round(float(first), 4))
        emit(f"fig9/{app}/opc_end", r["us"], round(float(last), 4))
        emit(f"fig9/{app}/convergence_gain", r["us"],
             round(float(last / max(first, 1e-9)), 4))


if __name__ == "__main__":
    run()
