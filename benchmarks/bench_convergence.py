"""Fig. 9: OPC timeline (fixed-size resample, order preserved) showing the
agent converging toward higher OPC across its episodes, plus warm-vs-cold
rows from the continual program-switch stream (one DQN threaded through app
switches vs a fresh DQN on the final phase).

Everything comes off cached batched sweeps — the shared figure grid
(`figure_grid`, one compiled sweep for all single-program figures) and the
shared continual stream (`cached_stream`, reused by bench_continual) — no
serial per-episode calls remain.
"""
import numpy as np

from benchmarks.common import (STREAM_EPISODES, STREAM_N_OPS_PER_APP, apps,
                               cached_stream, emit, figure_grid, grid_us)


def run():
    cached = figure_grid()
    res, grid = cached["res"], cached["grid"]
    us = grid_us(cached)
    lanes = {sc.name: i for i, sc in enumerate(grid)}
    for app in apps():
        i = lanes[f"{app}/bnmp/aimm/s0"]
        eps = grid[i].total_episodes
        tl = np.concatenate([res.opc_timeline(i, e, samples=16)
                             for e in range(eps)])
        first, last = tl[:16].mean(), tl[-16:].mean()
        emit(f"fig9/{app}/opc_start", us, round(float(first), 4))
        emit(f"fig9/{app}/opc_end", us, round(float(last), 4))
        emit(f"fig9/{app}/convergence_gain", us,
             round(float(last / max(first, 1e-9)), 4))

    # Warm vs cold start on the continual stream's final phase: the warm
    # agent (threaded through every earlier program phase) starts its first
    # episode where the cold agent only ends up after training.
    stream = cached_stream("switch", n_ops_per_app=STREAM_N_OPS_PER_APP,
                           episodes=STREAM_EPISODES)
    warm, cold = stream["res"].phases[-1], stream["cold"]
    sus = stream["us"] / max(len(stream["res"].phases) + 1, 1)
    lane_w = next(i for i, sc in enumerate(warm.scenarios)
                  if sc.mapper == "aimm")
    lane_c = next(i for i, sc in enumerate(cold.scenarios)
                  if sc.mapper == "aimm")
    w0 = float(warm.opc_timeline(lane_w, 0, samples=16).mean())
    c0 = float(cold.opc_timeline(lane_c, 0, samples=16).mean())
    emit("fig9/continual/warm_first_episode_opc", sus, round(w0, 4))
    emit("fig9/continual/cold_first_episode_opc", sus, round(c0, 4))
    emit("fig9/continual/warm_vs_cold_gain", sus,
         round(w0 / max(c0, 1e-9), 4))


if __name__ == "__main__":
    run()
