"""Fig. 7: average hop count and computation utilization, TOM vs AIMM."""
from benchmarks.common import apps, cached_episode, emit
from repro.nmp.stats import summarize


def run():
    for app in apps():
        for mapper in ("none", "tom", "aimm"):
            r = cached_episode(app, "bnmp", mapper)
            s = summarize(r["res"])
            tag = {"none": "B", "tom": "TOM", "aimm": "AIMM"}[mapper]
            emit(f"fig7/{app}/{tag}/hops", r["us"], round(s["mean_hops"], 3))
            emit(f"fig7/{app}/{tag}/util", r["us"],
                 round(s["compute_util"], 4))


if __name__ == "__main__":
    run()
