"""Fig. 7: average hop count and computation utilization, TOM vs AIMM,
served from the shared batched figure grid (common.figure_grid)."""
from benchmarks.common import apps, emit, figure_grid, grid_us, lane_summary


def run():
    cached = figure_grid()
    us = grid_us(cached)
    for app in apps():
        for mapper in ("none", "tom", "aimm"):
            s = lane_summary(cached, f"{app}/bnmp/{mapper}/s0")
            tag = {"none": "B", "tom": "TOM", "aimm": "AIMM"}[mapper]
            emit(f"fig7/{app}/{tag}/hops", us, round(s["mean_hops"], 3))
            emit(f"fig7/{app}/{tag}/util", us, round(s["compute_util"], 4))


if __name__ == "__main__":
    run()
