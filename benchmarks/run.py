"""Benchmark runner: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Env:
  BENCH_FULL=1   paper-scale traces/episodes (slower)
  BENCH_ONLY=fig6,fig9  run a subset
"""
import os
import sys
import traceback

MODULES = [
    ("engine_sweep", "benchmarks.bench_engine"),
    ("fig5_workloads", "benchmarks.bench_workloads"),
    ("fig6_execution_time", "benchmarks.bench_execution_time"),
    ("fig7_hops_util", "benchmarks.bench_hopcount_util"),
    ("fig8_opc", "benchmarks.bench_opc"),
    ("fig9_convergence", "benchmarks.bench_convergence"),
    ("fig10_migration", "benchmarks.bench_migration"),
    ("fig11_mesh_scaling", "benchmarks.bench_mesh_scaling"),
    ("fig12_multiprogram", "benchmarks.bench_multiprogram"),
    ("continual_stream", "benchmarks.bench_continual"),
    ("fleet", "benchmarks.bench_fleet"),
    ("serving", "benchmarks.bench_serving"),
    ("faults", "benchmarks.bench_faults"),
    ("topology_axis", "benchmarks.bench_topology"),
    ("epoch_kernel", "benchmarks.bench_epoch_kernel"),
    ("fig13_sensitivity", "benchmarks.bench_sensitivity"),
    ("fig14_energy", "benchmarks.bench_energy"),
    ("kernels", "benchmarks.bench_kernels"),
    ("roofline", "benchmarks.bench_roofline"),
]


def main() -> None:
    only = os.environ.get("BENCH_ONLY")
    wanted = only.split(",") if only else None
    print("name,us_per_call,derived")
    for tag, mod_name in MODULES:
        if wanted and not any(w in tag for w in wanted):
            continue
        try:
            import importlib
            mod = importlib.import_module(mod_name)
            mod.run()
        except Exception as e:
            traceback.print_exc(file=sys.stderr)
            print(f"{tag}/ERROR,0,{type(e).__name__}", flush=True)


if __name__ == '__main__':
    main()
