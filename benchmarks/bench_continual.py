"""Continual-learning benchmark: warm-start vs cold-start on a program-switch
stream (the paper's core "continuously evaluates and learns ... for any
application" claim, §7.5).

Protocol: the default `switch` stream (KM -> KM+SC -> SC) runs once *warm* —
one DQN lineage threaded through every phase by `continual.run_stream` — and
the final phase reruns *cold* (fresh agent).  On that final phase we measure
**invocations-to-threshold-OPC**: the number of agent invocations until the
rolling (window `ROLL_K` epochs) OPC first reaches `THRESH_FRAC` x the cold
run's converged OPC (its final-quarter rolling mean).  A warm agent that
truly carries its mapping knowledge across program switches reaches the
threshold in strictly fewer invocations — and with a lower lifetime ε it
also stops paying cold-start exploration noise.

Rows are emitted as CSV like every benchmark; the machine-readable record
lands in ``bench_out/BENCH_continual.json`` (schema: benchmarks/README.md).
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import (FULL, STREAM_EPISODES, STREAM_N_OPS_PER_APP,
                               cached_stream, emit)

JSON_PATH = os.environ.get("BENCH_CONTINUAL_JSON",
                           "bench_out/BENCH_continual.json")

STREAM = "switch"
N_OPS_PER_APP = STREAM_N_OPS_PER_APP
EPISODES = STREAM_EPISODES
ROLL_K = 8            # rolling-mean window (epochs) for the OPC timeline
THRESH_FRAC = 0.9     # threshold = frac x cold converged (final-quarter) OPC


def _phase_timeline(res, lane: int):
    """(opc, invocations) per valid epoch, episodes concatenated in order."""
    sc = res.scenarios[lane]
    eps = sc.total_episodes
    opc = np.asarray(res.metrics["opc_t"][lane][:eps]).reshape(-1)
    val = np.asarray(res.metrics["valid_t"][lane][:eps]).reshape(-1)
    inv = np.asarray(res.metrics["invoke_t"][lane][:eps]).reshape(-1)
    mask = val > 0
    return opc[mask], inv[mask]


def _rolling(x: np.ndarray, k: int) -> np.ndarray:
    c = np.cumsum(np.insert(x.astype(np.float64), 0, 0.0))
    return (c[k:] - c[:-k]) / k


def invocations_to_threshold(opc: np.ndarray, inv: np.ndarray,
                             thresh: float, k: int = ROLL_K):
    """Invocations consumed before the rolling OPC first reaches `thresh`
    (None when it never does)."""
    r = _rolling(opc, k)
    hit = np.nonzero(r >= thresh)[0]
    if hit.size == 0:
        return None, None
    epoch = int(hit[0] + k - 1)                # last epoch of the window
    return int(np.cumsum(inv)[epoch]), epoch


def _aimm_lane(res):
    return next(i for i, sc in enumerate(res.scenarios)
                if sc.mapper == "aimm")


def run():
    cached = cached_stream(STREAM, n_ops_per_app=N_OPS_PER_APP,
                           episodes=EPISODES)
    res, cold = cached["res"], cached["cold"]
    warm = res.phases[-1]
    us = cached["us"] / max(len(res.phases) + 1, 1)
    lane_w, lane_c = _aimm_lane(warm), _aimm_lane(cold)

    opc_w, inv_w = _phase_timeline(warm, lane_w)
    opc_c, inv_c = _phase_timeline(cold, lane_c)
    roll_c = _rolling(opc_c, ROLL_K)
    converged = float(roll_c[-max(roll_c.size // 4, 1):].mean())
    thresh = THRESH_FRAC * converged
    inv_to_w, ep_to_w = invocations_to_threshold(opc_w, inv_w, thresh)
    inv_to_c, ep_to_c = invocations_to_threshold(opc_c, inv_c, thresh)

    store = res.store
    tag = store.tags[0]
    phases = [sc.name.split(":")[1].split("/")[0]
              for phase in cached["stream"] for sc in phase[-1:]]
    name = "continual/" + "-".join(phases)

    emit(f"{name}/threshold_opc", us, round(thresh, 4))
    emit(f"{name}/warm_inv_to_threshold", us, inv_to_w)
    emit(f"{name}/cold_inv_to_threshold", us, inv_to_c)
    if inv_to_w is not None and inv_to_c is not None:
        emit(f"{name}/inv_saved_warm_vs_cold", us, inv_to_c - inv_to_w)
    emit(f"{name}/warm_final_opc", us,
         round(warm.episode_summary(lane_w)["opc"], 4))
    emit(f"{name}/cold_final_opc", us,
         round(cold.episode_summary(lane_c)["opc"], 4))
    emit(f"{name}/warm_mean_opc", us, round(float(opc_w.mean()), 4))
    emit(f"{name}/cold_mean_opc", us, round(float(opc_c.mean()), 4))
    emit(f"{name}/lineage_global_step", us, store.global_step(tag))

    record = {
        "stream": {"name": STREAM, "phases": phases,
                   "n_ops_per_app": N_OPS_PER_APP, "episodes": EPISODES,
                   "full": FULL},
        "protocol": {"roll_k": ROLL_K, "thresh_frac": THRESH_FRAC,
                     "threshold_opc": round(thresh, 6),
                     "converged_cold_opc": round(converged, 6)},
        "final_phase": {
            "warm": {"inv_to_threshold": inv_to_w,
                     "epochs_to_threshold": ep_to_w,
                     "invocations_total": int(inv_w.sum()),
                     "mean_opc": round(float(opc_w.mean()), 6),
                     "final_opc": round(
                         warm.episode_summary(lane_w)["opc"], 6)},
            "cold": {"inv_to_threshold": inv_to_c,
                     "epochs_to_threshold": ep_to_c,
                     "invocations_total": int(inv_c.sum()),
                     "mean_opc": round(float(opc_c.mean()), 6),
                     "final_opc": round(
                         cold.episode_summary(lane_c)["opc"], 6)},
        },
        "lineage": {"tag": tag, "global_step": store.global_step(tag),
                    "train_steps": store.meta[tag].get("train_steps"),
                    "phases_served": store.meta[tag].get("phases")},
        "wall_s": round(cached["us"] / 1e6, 3),
        "n_devices": warm.n_devices,
    }
    # Always present: None only when *neither* run reaches the threshold;
    # a warm run that never reaches a threshold the cold run does reach is a
    # determinate (and alarming) False, not missing data.
    if inv_to_w is None and inv_to_c is None:
        record["warm_reaches_threshold_first"] = None
    elif inv_to_w is None or inv_to_c is None:
        record["warm_reaches_threshold_first"] = inv_to_c is None
    else:
        record["warm_reaches_threshold_first"] = inv_to_w < inv_to_c

    os.makedirs(os.path.dirname(JSON_PATH) or ".", exist_ok=True)
    with open(JSON_PATH, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    print(f"# wrote {JSON_PATH}", flush=True)


if __name__ == "__main__":
    run()
