"""`make profile`: capture a JAX profiler trace of one warm batched grid.

Writes a TensorBoard-compatible trace under bench_out/profile/ (open with
`tensorboard --logdir bench_out/profile` or xprof).  The grid is the same
18-lane sweep bench_engine times, compiled first so the trace contains only
the steady-state epoch scan, not tracing/compilation.
"""
from __future__ import annotations

import os

import jax

from benchmarks.bench_engine import _grid

LOG_DIR = os.environ.get("PROFILE_DIR", "bench_out/profile")


def run():
    from repro.nmp.sweep import run_grid

    _, grid = _grid()
    run_grid(grid)                        # compile + warm outside the trace
    os.makedirs(LOG_DIR, exist_ok=True)
    with jax.profiler.trace(LOG_DIR):
        res = run_grid(grid)
        jax.block_until_ready(res.final_env)
    print(f"profile trace written to {LOG_DIR}")


if __name__ == "__main__":
    run()
