"""Fig. 8: normalized memory operations-per-cycle (OPC) per app/technique,
served from the shared batched figure grid (common.figure_grid).  With
BENCH_SEEDS > 1 every AIMM point also emits its mean±std variance band over
the folded seed axis (`common.lane_band`)."""
from benchmarks.common import (SEEDS, apps, emit, figure_grid, grid_us,
                               lane_band, lane_summary)


def run():
    cached = figure_grid()
    us = grid_us(cached)
    for app in apps():
        for tech in ("bnmp", "ldb", "pei"):
            base = lane_summary(cached, f"{app}/{tech}/none/s0")["opc"]
            for mapper in ("tom", "aimm"):
                opc = lane_summary(cached, f"{app}/{tech}/{mapper}/s0")["opc"]
                emit(f"fig8/{app}/{tech}/{mapper.upper()}", us,
                     round(opc / max(base, 1e-9), 4))
            if len(SEEDS) > 1:
                band = lane_band(cached, f"{app}/{tech}/aimm/s0")
                emit(f"fig8/{app}/{tech}/AIMM_band", us,
                     f"{band['opc_mean'] / max(base, 1e-9):.4f}"
                     f"±{band['opc_std'] / max(base, 1e-9):.4f}"
                     f"(n={band['n']})")


if __name__ == "__main__":
    run()
