"""Fig. 8: normalized memory operations-per-cycle (OPC) per app/technique."""
from benchmarks.common import apps, cached_episode, emit
from repro.nmp.stats import summarize


def run():
    for app in apps():
        for tech in ("bnmp", "ldb", "pei"):
            base = summarize(cached_episode(app, tech, "none")["res"])["opc"]
            for mapper in ("tom", "aimm"):
                r = cached_episode(app, tech, mapper)
                opc = summarize(r["res"])["opc"]
                emit(f"fig8/{app}/{tech}/{mapper.upper()}", r["us"],
                     round(opc / max(base, 1e-9), 4))


if __name__ == "__main__":
    run()
