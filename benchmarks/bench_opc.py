"""Fig. 8: normalized memory operations-per-cycle (OPC) per app/technique,
served from the shared batched figure grid (common.figure_grid)."""
from benchmarks.common import apps, emit, figure_grid, grid_us, lane_summary


def run():
    cached = figure_grid()
    us = grid_us(cached)
    for app in apps():
        for tech in ("bnmp", "ldb", "pei"):
            base = lane_summary(cached, f"{app}/{tech}/none/s0")["opc"]
            for mapper in ("tom", "aimm"):
                opc = lane_summary(cached, f"{app}/{tech}/{mapper}/s0")["opc"]
                emit(f"fig8/{app}/{tech}/{mapper.upper()}", us,
                     round(opc / max(base, 1e-9), 4))


if __name__ == "__main__":
    run()
