"""Shared benchmark infrastructure.

Every benchmark prints `name,us_per_call,derived` CSV rows (one per paper
table/figure datapoint). `us_per_call` is the wall time of the underlying
simulator/compile call; `derived` is the paper-comparable quantity.

Two execution paths are provided:

  cached_episode : one serial (app, technique, mapper) cell, memoized —
                   used by benchmarks that need the full EpisodeResult
                   (per-epoch metrics, final env state).
  cached_grid    : a whole scenario grid through the batched sweep engine
                   (`repro.nmp.sweep.run_grid`), memoized — one compile and
                   one dispatch for every cell of the grid.
"""
from __future__ import annotations

import contextlib
import os
import time

import numpy as np

FULL = os.environ.get("BENCH_FULL", "0") == "1"

# paper protocol: 5 episodes, DNN persisted; FULL widens the app set
N_OPS = 16384
EPISODES = 5
APPS_FAST = ("BP", "KM", "PR", "RBM", "SPMV") if not FULL else None
# seed replicas per figure cell: the sweep folds them into a vmapped seed
# axis (variance bands come back per lane); BENCH_SEEDS widens the axis.
_raw_seeds = os.environ.get("BENCH_SEEDS", "3" if FULL else "1")
try:
    _n_seeds = int(_raw_seeds)
except ValueError:
    raise ValueError(f"BENCH_SEEDS={_raw_seeds!r}: expected a positive "
                     "integer") from None
if _n_seeds < 1:
    raise ValueError(f"BENCH_SEEDS={_n_seeds} must be >= 1")
SEEDS = tuple(range(_n_seeds))


def apps():
    from repro.nmp.traces import APPS
    return APPS if FULL else APPS_FAST


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.us = (time.time() - self.t0) * 1e6


# ---------------------------------------------------------------------------
# Interleaved A/B harness (benchmarks/README.md "measurement protocol").
# The performance benchmarks used to hand-roll this loop; they share one
# implementation so every A/B record means the same thing.
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def env_overrides(**kv):
    """Temporarily set/clear env knobs (None clears).  Knobs like
    REPRO_SWEEP_MESH / REPRO_EPOCH_BACKEND are read per run_grid call (the
    resolved value is a static jit argument), so flipping them between calls
    selects distinct resident programs without recompiling."""
    old = {k: os.environ.get(k) for k in kv}
    try:
        for k, v in kv.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v
        yield
    finally:
        for k, v in old.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


def metrics_equal(a, b) -> bool:
    """Exact metric-dict equality of two SweepResults (same keys, every
    array bit-identical) — the exactness check each A/B record reports."""
    return (set(a.metrics) == set(b.metrics)
            and all(np.array_equal(np.asarray(a.metrics[k]),
                                   np.asarray(b.metrics[k]))
                    for k in a.metrics))


def ab_orders(reps: int):
    """Arm orders for an interleaved best-of A/B: alternate which arm runs
    first each rep, so neither arm systematically sees the warmer host."""
    for rep in range(reps):
        yield (0, 1) if rep % 2 == 0 else (1, 0)


def ab_compare(run_a, run_b, reps: int = 5, env_a: dict | None = None,
               env_b: dict | None = None, warmup: bool = True) -> dict:
    """Interleaved A/B, min-of-warm-reps: both arms stay resident (distinct
    compiled programs) and alternate, the min of each arm's warm reps is the
    signal on a noisy shared-core container.  `env_a`/`env_b` are
    env-override dicts applied around the corresponding arm (None values
    clear).  Returns {"a_s", "b_s", "a_all", "b_all", "improvement",
    "last_a", "last_b"} — improvement = a_s / b_s (B is the new path)."""
    def arm(fn, env):
        with env_overrides(**(env or {})):
            t0 = time.time()
            out = fn()
            return time.time() - t0, out
    last = [None, None]
    if warmup:                           # compile both resident program sets
        _, last[0] = arm(run_a, env_a)
        _, last[1] = arm(run_b, env_b)
    walls: list[list[float]] = [[], []]
    for order in ab_orders(reps):
        for i in order:
            w, last[i] = arm((run_a, run_b)[i], (env_a, env_b)[i])
            walls[i].append(w)
    a_s, b_s = min(walls[0]), min(walls[1])
    return {"a_s": a_s, "b_s": b_s, "a_all": walls[0], "b_all": walls[1],
            "improvement": a_s / b_s if b_s else float("inf"),
            "last_a": last[0], "last_b": last[1]}


def min_warm(fn, reps: int) -> tuple[float, list[float]]:
    """Min-of-N warm wall time of a single resident path (the single-arm
    guard rows); returns (min_s, all_s)."""
    walls = []
    for _ in range(reps):
        t0 = time.time()
        fn()
        walls.append(time.time() - t0)
    return min(walls), walls


_EPISODE_CACHE: dict = {}


def cached_episode(app: str, technique: str, mapper: str, **kw):
    """Memoized (app, technique, mapper) runs shared across benchmarks."""
    from repro.nmp import NMPConfig, make_trace, run_episode, run_program
    key = (app, technique, mapper, N_OPS, tuple(sorted(kw.items())))
    if key in _EPISODE_CACHE:
        return _EPISODE_CACHE[key]
    cfg = kw.pop("cfg", NMPConfig())
    tr = make_trace(app, n_ops=N_OPS)
    with Timer() as t:
        if mapper == "aimm":
            results = run_program(tr, cfg, technique=technique, mapper="aimm",
                                  episodes=EPISODES, seed=0, **kw)
            # converged behaviour: greedy evaluation episode with the trained
            # DNN (paper's steady-state claim; exploration off)
            res = run_episode(tr, cfg, technique=technique, mapper="aimm",
                              agent=results[-1].agent, explore=False, **kw)
            res_all = results + [res]
        else:
            res = run_episode(tr, cfg, technique=technique, mapper=mapper,
                              **kw)
            res_all = [res]
    out = {"res": res, "all": res_all, "us": t.us, "trace": tr}
    _EPISODE_CACHE[key] = out
    return out


_GRID_CACHE: dict = {}


def cached_grid(grid_name: str, cfg=None, **kw):
    """Memoized batched run of a named scenario grid (see repro.nmp.scenarios).

    `cfg` overrides the NMPConfig the sweep runs under (it is part of the
    memo key, so e.g. mesh-scaling and sensitivity points cache separately;
    the device-mesh signature is part of the key too, so cached results
    never cross a REPRO_SWEEP_DEVICES change, and builder kwargs — including
    figure_grid's seeds=SEEDS — key as before).
    Returns {"res": SweepResult, "grid": [Scenario], "us": wall_us}; lanes are
    addressed by `Scenario.name` via `lane_summary`."""
    from repro.nmp import NMPConfig, partition, scenarios, sweep
    cfg = cfg or NMPConfig()
    # seeds (when a builder takes them, e.g. figure_grid's seeds=SEEDS) are
    # part of kw and therefore of the key already.
    key = (grid_name, str(cfg), partition.mesh_signature(),
           tuple(sorted((k, str(v)) for k, v in kw.items())))
    if key in _GRID_CACHE:
        return _GRID_CACHE[key]
    grid = scenarios.build(grid_name, **kw)
    res = sweep.run_grid(grid, cfg)
    out = {"res": res, "grid": grid, "us": res.wall_s * 1e6}
    _GRID_CACHE[key] = out
    return out


def figure_grid(cfg=None, techniques=("bnmp", "ldb", "pei"),
                mappers=("none", "tom", "aimm"), apps_=None):
    """The shared app x technique x mapper grid behind the single-program
    figures (fig6-11, 14): every AIMM lane trains for EPISODES episodes and
    appends a greedy eval episode (the paper's converged-behaviour protocol).
    One `sweep.run_grid` call (memoized) covers all of them; with
    BENCH_SEEDS > 1 every cell carries a folded seed axis and figures can
    report mean±std bands via `lane_band`."""
    return cached_grid("single", cfg=cfg, apps=apps_ or apps(),
                       techniques=techniques, mappers=mappers, n_ops=N_OPS,
                       seeds=SEEDS, aimm_episodes=EPISODES,
                       eval_episode=True)


_STREAM_CACHE: dict = {}

# Shared continual-stream protocol: bench_continual and the fig9/continual
# rows must request the *same* stream or the cached_stream memo splits and
# the most expensive computation (warm stream + cold final phase) runs twice.
STREAM_N_OPS_PER_APP = N_OPS // 4 if FULL else N_OPS // 8
STREAM_EPISODES = 5 if FULL else 3


def cached_stream(name: str = "switch", cfg=None, **kw):
    """Memoized continual-stream run shared by the continual benchmarks.

    Executes a named program-phase stream (`repro.nmp.scenarios.STREAMS`)
    twice over its final phase: once *warm* (one PolicyStore threaded through
    every phase — the paper's continual-learning protocol) and once *cold*
    (the final phase alone with a fresh store), so warm-vs-cold rows come
    from one cached computation.  Returns {"stream", "res" (StreamResult),
    "cold" (SweepResult of the final phase), "us"}."""
    from repro.nmp import NMPConfig, partition, scenarios, sweep
    from repro.nmp.continual import run_stream
    cfg = cfg or NMPConfig()
    key = (name, str(cfg), partition.mesh_signature(),
           tuple(sorted((k, str(v)) for k, v in kw.items())))
    if key in _STREAM_CACHE:
        return _STREAM_CACHE[key]
    stream = scenarios.build_stream(name, **kw)
    with Timer() as t:
        res = run_stream(stream, cfg)
        cold = sweep.run_grid(stream[-1], cfg)   # fresh store => cold lineage
    out = {"stream": stream, "res": res, "cold": cold, "us": t.us}
    _STREAM_CACHE[key] = out
    return out


def grid_us(cached: dict) -> float:
    """Per-lane wall-time attribution for a cached grid's CSV rows: the whole
    sweep's wall time split evenly over its lanes."""
    return cached["us"] / len(cached["grid"])


def lane_index(cached: dict, name: str) -> int:
    for i, sc in enumerate(cached["grid"]):
        if sc.name == name:
            return i
    raise KeyError(name)


def lane_summary(cached: dict, name: str, episode: int | None = None) -> dict:
    """Summary dict for the lane whose Scenario.name == `name`."""
    return cached["res"].episode_summary(lane_index(cached, name), episode)


def lane_band(cached: dict, name: str, episode: int | None = None) -> dict:
    """Variance band (mean±std across the folded seed axis) for the seed
    group containing the lane named `name` — see SweepResult.variance_band."""
    return cached["res"].variance_band(lane_index(cached, name), episode)
