"""Shared benchmark infrastructure.

Every benchmark prints `name,us_per_call,derived` CSV rows (one per paper
table/figure datapoint). `us_per_call` is the wall time of the underlying
simulator/compile call; `derived` is the paper-comparable quantity.
"""
from __future__ import annotations

import os
import time

import numpy as np

FULL = os.environ.get("BENCH_FULL", "0") == "1"

# paper protocol: 5 episodes, DNN persisted; FULL widens the app set
N_OPS = 16384
EPISODES = 5
APPS_FAST = ("BP", "KM", "PR", "RBM", "SPMV") if not FULL else None


def apps():
    from repro.nmp.traces import APPS
    return APPS if FULL else APPS_FAST


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.us = (time.time() - self.t0) * 1e6


_EPISODE_CACHE: dict = {}


def cached_episode(app: str, technique: str, mapper: str, **kw):
    """Memoized (app, technique, mapper) runs shared across benchmarks."""
    from repro.nmp import NMPConfig, make_trace, run_episode, run_program
    key = (app, technique, mapper, N_OPS, tuple(sorted(kw.items())))
    if key in _EPISODE_CACHE:
        return _EPISODE_CACHE[key]
    cfg = kw.pop("cfg", NMPConfig())
    tr = make_trace(app, n_ops=N_OPS)
    with Timer() as t:
        if mapper == "aimm":
            results = run_program(tr, cfg, technique=technique, mapper="aimm",
                                  episodes=EPISODES, seed=0, **kw)
            # converged behaviour: greedy evaluation episode with the trained
            # DNN (paper's steady-state claim; exploration off)
            res = run_episode(tr, cfg, technique=technique, mapper="aimm",
                              agent=results[-1].agent, explore=False, **kw)
            res_all = results + [res]
        else:
            res = run_episode(tr, cfg, technique=technique, mapper=mapper,
                              **kw)
            res_all = [res]
    out = {"res": res, "all": res_all, "us": t.us, "trace": tr}
    _EPISODE_CACHE[key] = out
    return out
