"""Fault-tolerance benchmark: recovery drills plus the no-fault overhead of
the serving layer's divergence guard (nmp.faults + nmp.serving).

Protocol, two halves:

  * **Overhead** — the same `N_TENANTS`-tenant fleet is drained through
    identical servers with no faults armed, alternating
    `divergence_guard=False` and guard-on (the default) for
    `OVERHEAD_REPS` pairs after a warmup drain; each arm keeps its fastest
    steady-state epochs/sec (host scheduling noise between whole drains far
    exceeds the guard's true cost).  The guard is the only standing cost of
    the robustness layer — every fault hook is a plain `is not None` check
    when unarmed — so the best-of ratio IS the robustness tax.  Target:
    < 2% (`overhead_pct` in the record; only post-compile ticks count).

  * **Recovery drills** — a fleet served under an armed `FaultPlan`: a
    transiently poisoned warm agent (caught by the guard, retried
    bit-identically), a persistently failing tenant (bounded retry ->
    quarantine, co-tenants unaffected), silent store corruption (lineage
    rollback to last-good version), and an on-disk checkpoint corruption
    (restore falls back to the newest intact step).  The counters from
    `MappingServer.stats()["faults"]` and the store land in the record,
    plus a bit-identical spot check of an unaffected tenant against its
    solo `run_stream`.

Rows are emitted as CSV like every benchmark; the machine-readable record
lands in ``bench_out/BENCH_faults.json`` (schema: benchmarks/README.md).
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import FULL, Timer, ab_orders, emit

JSON_PATH = os.environ.get("BENCH_FAULTS_JSON",
                           "bench_out/BENCH_faults.json")
SERVING_JSON = os.environ.get("BENCH_SERVING_JSON",
                              "bench_out/BENCH_serving.json")

N_TENANTS = 24 if FULL else 12
N_SLOTS = 4
N_PHASES = 4
N_OPS_PER_APP = 1024 if FULL else 512
OVERHEAD_TARGET_PCT = 2.0
OVERHEAD_REPS = 3


def _drain(fleet, cfg, **server_kw):
    from repro.nmp.serving import MappingServer
    srv = MappingServer(cfg, n_slots=N_SLOTS, **server_kw)
    for tid, stream in fleet.items():
        srv.submit(tid, stream)
    srv.run()
    return srv


def run():
    from repro.nmp import NMPConfig, faults
    from repro.nmp.continual import PolicyStore, run_stream
    from repro.nmp.engine import default_agent_cfg
    from repro.nmp.faults import FaultEvent, FaultPlan
    from repro.nmp.scenarios import tenant_fleet
    from repro.nmp.serving import solo_stream

    cfg = NMPConfig()
    fleet = tenant_fleet(n_tenants=N_TENANTS, n_phases=N_PHASES,
                         n_ops_per_app=N_OPS_PER_APP)

    # -- overhead: guard off vs guard on, no faults armed ---------------
    # Alternating best-of-N: host scheduling noise between whole drains far
    # exceeds the guard's true cost, so each arm keeps its fastest run.
    _drain(fleet, cfg)               # warmup: both arms start with the
                                     # resident programs compiled
    reps_off, reps_on = [], []
    with Timer() as t_on:
        for order in ab_orders(OVERHEAD_REPS):
            # ab_orders alternates which arm goes first: whichever drain runs
            # second in a pair tends to see a warmer host, which would bias a
            # fixed order by more than the guard costs
            for guard in (bool(i) for i in order):
                st = _drain(fleet, cfg, divergence_guard=guard).stats()
                assert st["tenants_done"] == N_TENANTS
                (reps_on if guard else reps_off).append(
                    st["steady_epochs_per_sec"] or 0.0)
            on = st                 # any stats dict: server shape for record
    eps_off, eps_on = max(reps_off), max(reps_on)
    overhead_pct = (100.0 * (eps_off - eps_on) / eps_off) if eps_off else 0.0

    # -- recovery drills ------------------------------------------------
    plan = FaultPlan([
        FaultEvent("poison_agent", at=2, tenant="t001"),   # transient NaN
    ] + [FaultEvent("fail_tick", at=i, tenant="t000")      # persistent fail
         for i in range(3, 12)])
    srv = _drain(fleet, cfg, faults=plan, max_phase_retries=1,
                 backoff_base_s=0.001)
    # silent store corruption mid-service on a fresh server
    from repro.nmp.serving import MappingServer
    srv2 = MappingServer(cfg, n_slots=N_SLOTS, backoff_base_s=0.001)
    srv2.submit("t", fleet["t002"])
    srv2.tick()
    srv2.tick()
    faults.poison_store_agent(srv2.store, "t")
    srv2.run()
    drill = srv.stats()["faults"]
    drill["rollbacks"] += srv2.stats()["faults"]["rollbacks"]
    recovered = (srv.tenant("t001").health == "healthy"
                 and srv.tenant("t001").done
                 and srv.tenant("t000").quarantined
                 and srv2.tenant("t").done)

    # bit-identical spot check of an unaffected tenant (after stats)
    spot = "t002"
    solo = run_stream(solo_stream(spot, fleet[spot]), cfg)
    identical = all(
        np.array_equal(srv.tenant_metrics(spot, pi)[k],
                       solo.phases[pi].metrics[k][0])
        for pi in range(N_PHASES) for k in solo.phases[pi].metrics)

    # -- checkpoint corruption fallback drill ---------------------------
    import tempfile
    with tempfile.TemporaryDirectory() as ckdir:
        cplan = FaultPlan([FaultEvent("corrupt_checkpoint", at=N_PHASES - 1,
                                      n_bytes=64)], seed=3)
        run_stream(solo_stream("ck", fleet["t003"]), cfg,
                   checkpoint_dir=ckdir, faults=cplan)
        restored = PolicyStore.restore(ckdir, default_agent_cfg(cfg))
        ck_fallbacks = restored.restore_fallbacks
        ck_step = restored.restored_step

    name = f"faults/{N_TENANTS}tenants_{on['n_slots']}slots"
    emit(f"{name}/guard_overhead_pct", t_on.us, round(overhead_pct, 3))
    emit(f"{name}/steady_eps_guard_off", t_on.us, round(eps_off, 1))
    emit(f"{name}/steady_eps_guard_on", t_on.us, round(eps_on, 1))
    emit(f"{name}/divergences_caught", t_on.us, drill["divergences"])
    emit(f"{name}/retries", t_on.us, drill["retries"])
    emit(f"{name}/quarantines", t_on.us, drill["quarantines"])
    emit(f"{name}/rollbacks", t_on.us, drill["rollbacks"])
    emit(f"{name}/checkpoint_fallback_steps", t_on.us, ck_fallbacks)
    emit(f"{name}/recovered_and_drained", t_on.us, recovered)
    emit(f"{name}/spot_check_bit_identical", t_on.us, identical)

    reference_eps = None
    if os.path.exists(SERVING_JSON):
        try:
            with open(SERVING_JSON) as f:
                reference_eps = json.load(f)["service"][
                    "steady_epochs_per_sec"]
        except (OSError, KeyError, json.JSONDecodeError):
            pass

    record = {
        "fleet": {"n_tenants": N_TENANTS, "n_phases": N_PHASES,
                  "n_ops_per_app": N_OPS_PER_APP, "full": FULL},
        "server": {"n_slots": on["n_slots"], "n_devices": on["n_devices"]},
        "overhead": {
            "steady_epochs_per_sec_guard_off": eps_off,
            "steady_epochs_per_sec_guard_on": eps_on,
            "overhead_pct": round(overhead_pct, 3),
            "target_pct": OVERHEAD_TARGET_PCT,
            "within_target": bool(overhead_pct <= OVERHEAD_TARGET_PCT),
            "reference_serving_eps": reference_eps,
        },
        "recovery": {
            **{k: int(v) for k, v in drill.items()},
            "checkpoint_fallback_steps": int(ck_fallbacks),
            "checkpoint_restored_step": int(ck_step),
            "recovered_and_drained": bool(recovered),
            "spot_check_bit_identical": bool(identical),
        },
        "wall_s": round(t_on.us / 1e6, 3),
    }
    os.makedirs(os.path.dirname(JSON_PATH) or ".", exist_ok=True)
    with open(JSON_PATH, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    print(f"# wrote {JSON_PATH}", flush=True)


if __name__ == "__main__":
    run()
