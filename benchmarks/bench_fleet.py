"""Fleet-scale sweep benchmark: a seed-wide grid on the 2-D (lanes x seeds)
device mesh vs the PR 7 1-D lane-sharded path.

The grid is seed-heavy on purpose — every app carries `SEEDS` AIMM replicas
(one seed group of L lanes x S seeds) plus one deterministic baseline lane
per app (a ragged S=1 group) — because that is exactly the shape where the
1-D path wastes devices: with the seed axis trapped inside the lane, a
4-device mesh must pad L lanes up to a multiple of 4 while every device
re-simulates all S seeds.  The 2-D path factors the mesh over both axes
(auto-chosen to minimize padded cells across the plan's groups), shares the
seed-invariant per-epoch work (op windows, row-buffer winners, PEI
thresholds) across the S replicas, and packs ragged groups by padded cost.

Protocol (interleaved A/B, min of warm reps — see benchmarks/README.md):

  A (baseline): REPRO_SWEEP_MESH=<n>x1, REPRO_SEED_SHARE=off — the 1-D
     lane-sharded inner-vmap path on the same devices.
  B (new):      auto-factored 2-D mesh, seed sharing on.

Both paths stay resident (distinct compiled programs) so reps alternate
without recompiling.  Recorded: warm wall, delivered epochs/sec (total and
per host), padding-waste ratio of both placements, the A/B improvement
factor, bit-identity of metrics across every tested mesh shape (<n>x1,
2x2, 1x4, auto), and serial-reference mismatches on a spot-check subset.

The record lands in ``bench_out/BENCH_fleet.json`` (read-modify-write:
``bench_mesh_scaling`` folds its device-mesh shape sweep into the same
file under ``device_mesh_sweep``).
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import (FULL, ab_compare, emit, env_overrides,
                               metrics_equal)

JSON_PATH = os.environ.get("BENCH_FLEET_JSON", "bench_out/BENCH_fleet.json")

SEEDS = int(os.environ.get("BENCH_FLEET_SEEDS", "32" if FULL else "8"))
N_OPS = 2048 if FULL else 512
EPISODES = 2
REPS = 5


def run():
    from repro.nmp import partition
    from repro.nmp import plan as plan_mod
    from repro.nmp.scenarios import single_program_grid
    from repro.nmp.sweep import run_grid, run_grid_serial
    from repro.nmp.traces import APPS

    apps = APPS if FULL else ("KM", "PR")
    grid = single_program_grid(apps=apps, mappers=("aimm",), n_ops=N_OPS,
                               seeds=tuple(range(SEEDS)),
                               aimm_episodes=EPISODES)
    grid += single_program_grid(apps=apps, mappers=("none",), n_ops=N_OPS,
                                seeds=(0,))
    n_dev = len(partition.sweep_devices())
    base = {"REPRO_SWEEP_MESH": f"{n_dev}x1", "REPRO_SEED_SHARE": "off"}
    new = {"REPRO_SWEEP_MESH": None, "REPRO_SEED_SHARE": None}  # auto + on

    # cold warmup (compiles both resident program sets) + interleaved A/B;
    # the min of the warm reps is the signal on this 2-core container
    # (benchmarks/README.md, shared harness in benchmarks/common.py)
    ab = ab_compare(lambda: run_grid(grid), lambda: run_grid(grid),
                    reps=REPS, env_a=base, env_b=new)
    res_base, res_new = ab["last_a"], ab["last_b"]
    bit_1d = metrics_equal(res_base, res_new)
    warm_base, warm_new = ab["a_all"], ab["b_all"]
    warm_b, warm_n = ab["a_s"], ab["b_s"]
    improvement = ab["improvement"]

    # bit-identity across every mesh shape that factors the device count
    shapes = {}
    for shape in ("2x2", "1x4"):
        dl, ds = (int(x) for x in shape.split("x"))
        if dl * ds != n_dev:
            continue
        with env_overrides(REPRO_SWEEP_MESH=shape, REPRO_SEED_SHARE=None):
            shapes[shape] = metrics_equal(res_new, run_grid(grid))
    mesh_identical = bit_1d and all(shapes.values())

    # serial spot check: a strided subset covering every app and both
    # mapper kinds (full serial at fleet scale would dwarf the benchmark)
    idxs = sorted(set(list(range(0, len(grid),
                                 max(1, len(grid) // 8)))[:8]
                      + [len(grid) - 1]))
    serial = run_grid_serial([grid[i] for i in idxs])
    mismatches = sum(
        1 for j, i in enumerate(idxs)
        if serial[j]["cycles"] != res_new.episode_summary(i)["cycles"])

    import jax
    lane_epochs = float(np.sum(res_new.metrics["epochs"]))
    eps_per_s = lane_epochs / warm_n
    n_hosts = jax.process_count()
    groups = [(g.n_lanes, g.n_seeds, g.n_episodes)
              for g in res_new.plan.groups]
    waste_new = plan_mod.padding_waste(res_new.plan, *res_new.mesh_shape)
    waste_base = plan_mod.padding_waste(res_base.plan, *res_base.mesh_shape)

    tag = f"fleet/cells{len(grid)}_s{SEEDS}"
    emit(f"{tag}/warm_1d_s", warm_b * 1e6, round(warm_b, 3))
    emit(f"{tag}/warm_2d_s", warm_n * 1e6, round(warm_n, 3))
    emit(f"{tag}/improvement_vs_1d", warm_n * 1e6, round(improvement, 3))
    emit(f"{tag}/epoch_steps_per_s", warm_n * 1e6, round(eps_per_s, 1))
    emit(f"{tag}/padding_waste_2d", warm_n * 1e6, round(waste_new, 4))
    emit(f"{tag}/padding_waste_1d", warm_b * 1e6, round(waste_base, 4))
    emit(f"{tag}/mesh_shapes_bit_identical", warm_n * 1e6, mesh_identical)
    emit(f"{tag}/metric_mismatches_vs_serial", warm_n * 1e6, mismatches)
    emit(f"{tag}/n_devices", warm_n * 1e6, res_new.n_devices)

    record = {
        "grid": {"cells": len(grid), "apps": list(apps), "seeds": SEEDS,
                 "n_ops": N_OPS, "aimm_episodes": EPISODES, "full": FULL,
                 "folded_lanes": res_new.plan.n_lanes,
                 "groups_lanes_seeds_episodes": groups},
        "mesh": {"n_devices": res_new.n_devices,
                 "shape_2d": list(res_new.mesh_shape),
                 "shape_1d": list(res_base.mesh_shape),
                 "n_hosts": n_hosts,
                 "process_index": jax.process_index()},
        "throughput": {
            "warm_1d_s": round(warm_b, 4),
            "warm_2d_s": round(warm_n, 4),
            "warm_1d_all": [round(w, 4) for w in warm_base],
            "warm_2d_all": [round(w, 4) for w in warm_new],
            "lane_epochs": lane_epochs,
            "epoch_steps_per_s": round(eps_per_s, 1),
            "epoch_steps_per_s_per_host": round(eps_per_s / n_hosts, 1),
            "improvement_vs_1d": round(improvement, 3),
        },
        "padding_waste": {"mesh_2d": round(waste_new, 4),
                          "mesh_1d": round(waste_base, 4)},
        "exactness": {
            "bit_identical_vs_1d": bool(bit_1d),
            "mesh_shapes_bit_identical": {**{f"{n_dev}x1_vs_auto": bool(
                bit_1d)}, **{f"{s}_vs_auto": bool(v)
                             for s, v in shapes.items()}},
            "serial_cells_checked": len(idxs),
            "metric_mismatches_vs_serial": mismatches,
        },
    }
    os.makedirs(os.path.dirname(JSON_PATH) or ".", exist_ok=True)
    existing = {}
    if os.path.exists(JSON_PATH):
        try:
            with open(JSON_PATH) as f:
                existing = json.load(f)
        except (OSError, json.JSONDecodeError):
            existing = {}
    existing.update(record)
    with open(JSON_PATH, "w") as f:
        json.dump(existing, f, indent=2)
        f.write("\n")
    print(f"# wrote {JSON_PATH}", flush=True)


if __name__ == "__main__":
    run()
