"""Multi-tenant serving benchmark: the streaming mapping service under a
64-tenant fleet (nmp.serving.MappingServer).

Protocol: `N_TENANTS` heterogeneous single-lane tenant streams (app cycle
offset + seed per tenant, `N_PHASES` phases each) are all submitted up
front and drained through `N_SLOTS` resident lane-slot programs with a
capacity-bounded PolicyStore (capacity < fleet size, >= slot count — so the
store evicts under pressure while in-flight tenants stay warm).  The server
double-buffers the next tick's host batch against the current device step.

Measured (the acceptance bar for the serving layer):

  * phase latency p50/p99 and steady-state epochs/sec — from ticks after
    the last compile (compile ticks are excluded from the percentiles and
    their total wall is recorded separately as `compile_s`);
  * slot occupancy and the recompile count after the first tick, which must
    be ZERO: the resident programs' static shapes never change as tenants
    arrive and depart;
  * store evictions with capacity < tenants;
  * per-tenant exactness: `SPOT_CHECKS` tenants re-run solo through
    `continual.run_stream` and compared bit-for-bit (recorded as
    `spot_checks_bit_identical`; the solo runs happen after the serving
    stats are captured so their compiles don't pollute the record).

Rows are emitted as CSV like every benchmark; the machine-readable record
lands in ``bench_out/BENCH_serving.json`` (schema: benchmarks/README.md).
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import FULL, Timer, emit

JSON_PATH = os.environ.get("BENCH_SERVING_JSON",
                           "bench_out/BENCH_serving.json")

N_TENANTS = int(os.environ.get("BENCH_SERVING_TENANTS",
                               "96" if FULL else "64"))
N_SLOTS = 16
N_PHASES = 3
N_OPS_PER_APP = 1024 if FULL else 512
STORE_CAPACITY = max(N_SLOTS, N_TENANTS // 2)   # < fleet, >= slots
SPOT_CHECKS = 2


def run():
    from repro.nmp import NMPConfig
    from repro.nmp.continual import run_stream
    from repro.nmp.scenarios import tenant_fleet
    from repro.nmp.serving import MappingServer, solo_stream

    cfg = NMPConfig()
    fleet = tenant_fleet(n_tenants=N_TENANTS, n_phases=N_PHASES,
                         n_ops_per_app=N_OPS_PER_APP)
    srv = MappingServer(cfg, n_slots=N_SLOTS, store_capacity=STORE_CAPACITY)
    with Timer() as t:
        for tid, stream in fleet.items():
            srv.submit(tid, stream)
        ticks = srv.run()
    stats = srv.stats()
    assert stats["tenants_done"] == N_TENANTS

    # exactness spot checks AFTER capturing stats: the solo reference runs
    # compile their own (1-lane) programs, which must not count against the
    # server's steady-state record
    spot = list(fleet)[:: max(N_TENANTS // SPOT_CHECKS, 1)][:SPOT_CHECKS]
    identical = True
    for tid in spot:
        solo = run_stream(solo_stream(tid, fleet[tid]), cfg)
        for pi in range(N_PHASES):
            served = srv.tenant_metrics(tid, pi)
            want = solo.phases[pi].metrics
            identical &= all(np.array_equal(served[k], want[k][0])
                             for k in want)

    us_tick = t.us / max(ticks, 1)
    name = f"serving/{N_TENANTS}tenants_{stats['n_slots']}slots"
    emit(f"{name}/phase_latency_p50_ms", us_tick,
         round(stats["phase_latency_p50_s"] * 1e3, 3))
    emit(f"{name}/phase_latency_p99_ms", us_tick,
         round(stats["phase_latency_p99_s"] * 1e3, 3))
    emit(f"{name}/compile_s", us_tick, round(stats["compile_s"], 3))
    emit(f"{name}/steady_epochs_per_sec", us_tick,
         round(stats["steady_epochs_per_sec"] or 0.0, 1))
    emit(f"{name}/slot_occupancy", us_tick,
         round(stats["slot_occupancy"], 4))
    emit(f"{name}/recompiles_after_first_tick", us_tick,
         stats["recompiles_after_first_tick"])
    emit(f"{name}/store_evictions", us_tick, stats["store"]["evictions"])
    emit(f"{name}/spot_checks_bit_identical", us_tick, identical)

    record = {
        "fleet": {"n_tenants": N_TENANTS, "n_phases": N_PHASES,
                  "n_ops_per_app": N_OPS_PER_APP, "full": FULL},
        "server": {"n_slots": stats["n_slots"],
                   "n_devices": stats["n_devices"],
                   "store_capacity": STORE_CAPACITY},
        "service": {k: stats[k] for k in (
            "ticks", "phases_served", "tenants_done", "tenants_removed",
            "phase_latency_p50_s", "phase_latency_p99_s", "compile_s",
            "slot_occupancy", "recompiles_total",
            "recompiles_after_first_tick",
            "steady_ticks", "steady_epochs_per_sec")},
        "store": stats["store"],
        "exactness": {"spot_check_tenants": spot,
                      "spot_checks_bit_identical": bool(identical)},
        "wall_s": round(t.us / 1e6, 3),
    }
    os.makedirs(os.path.dirname(JSON_PATH) or ".", exist_ok=True)
    with open(JSON_PATH, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    print(f"# wrote {JSON_PATH}", flush=True)


if __name__ == "__main__":
    run()
