"""Topology axis benchmark: learned-AIMM vs the unmanaged baseline on every
cube interconnect (mesh2d / torus2d / ring / dragonfly), plus a warm-grid
throughput guard for the tensorized `link_loads` on the standard 18-cell
mesh grid.

Writes ``bench_out/BENCH_topology.json``:

  * ``topologies.<name>``: baseline OPC, learned-AIMM OPC (greedy eval
    episode after training) and the AIMM/baseline ratio — the paper's central
    question
    ("does the learned mapping adapt?") asked per interconnect.  The whole
    axis is ONE mixed-topology `run_grid` call: the plan layer compiles one
    program per (topology, agent-mode) group.
  * ``mesh_grid_warm``: min-of-N warm wall time of the same 18-cell mesh
    grid bench_engine times, compared against the pinned PR 3 measurement —
    the routing-tensor refactor (gather + einsum instead of XY indicator
    outer-products) must not regress the mesh hot path.

``PR3_BASELINE`` is PR 3's own quiet-machine record (min of warm runs on
the reference container) with the plan/partition/execute engine and the
historical XY `link_loads`; a same-session interleaved A/B against the
pre-topology XY engine measured 0.411s (tensorized) vs 0.424s (XY) — parity
at the min under this container's noise.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import FULL, N_OPS, emit, min_warm

JSON_PATH = os.environ.get("BENCH_TOPOLOGY_JSON",
                           "bench_out/BENCH_topology.json")

# PR 3 engine (XY indicator-outer-product link_loads), default 18-cell grid:
# the warm_s PR 3's BENCH_engine.json recorded on the reference container.
PR3_BASELINE = {"warm_s": 0.447, "n_ops": 2048, "lanes": 18,
                "note": "PR 3 engine record, reference container, min-warm"}

TOPO_APP = "KM"
AIMM_EPISODES = 5 if FULL else 3


def run():
    from repro.nmp import partition
    from repro.nmp.sweep import run_grid
    from repro.nmp.scenarios import topology_grid
    from repro.nmp.topology import TOPOLOGIES
    from benchmarks.bench_engine import _grid

    # ---- per-topology learned vs baseline (one mixed-topology sweep) ----
    n_ops = N_OPS // 2 if FULL else N_OPS // 4
    # Converged-behaviour protocol per interconnect: AIMM lanes train for
    # AIMM_EPISODES episodes and append a greedy eval episode (the figure
    # benchmarks' protocol); episode_summary defaults to the eval episode.
    grid = topology_grid(apps=(TOPO_APP,), n_ops=n_ops,
                         mappers=("none", "aimm"),
                         aimm_episodes=AIMM_EPISODES, eval_episode=True)
    res = run_grid(grid)
    topo_rows = {}
    for name in TOPOLOGIES:
        base = res.episode_summary(
            next(i for i, sc in enumerate(grid)
                 if sc.topology == name and sc.mapper == "none"))
        aimm = res.episode_summary(
            next(i for i, sc in enumerate(grid)
                 if sc.topology == name and sc.mapper == "aimm"))
        ratio = aimm["opc"] / max(base["opc"], 1e-9)
        topo_rows[name] = {
            "baseline_opc": round(base["opc"], 6),
            "aimm_opc": round(aimm["opc"], 6),
            "aimm_over_baseline": round(ratio, 4),
            "aimm_migrations": aimm["migrations"],
            "baseline_mean_hops": round(base["mean_hops"], 4),
            "aimm_mean_hops": round(aimm["mean_hops"], 4),
        }
        us = res.wall_s * 1e6 / len(grid)
        emit(f"topology/{name}/baseline_opc", us, topo_rows[name]["baseline_opc"])
        emit(f"topology/{name}/aimm_opc", us, topo_rows[name]["aimm_opc"])
        emit(f"topology/{name}/aimm_over_baseline", us,
             topo_rows[name]["aimm_over_baseline"])

    # ---- tensorized link_loads: warm mesh-grid throughput guard ----
    mesh_n_ops, mesh_grid = _grid()
    run_grid(mesh_grid)                         # compile + first dispatch
    reps = 9 if FULL else 5
    warm_s, warm = min_warm(lambda: run_grid(mesh_grid), reps)
    emit("topology/mesh_grid/warm_s", warm_s * 1e6, round(warm_s, 3))

    record = {
        "grid": {"app": TOPO_APP, "n_ops": n_ops,
                 "topologies": sorted(TOPOLOGIES),
                 "aimm_episodes": AIMM_EPISODES, "full": FULL,
                 "lanes": len(grid)},
        "mesh": partition.mesh_desc(partition.build_mesh()),
        "topologies": topo_rows,
        "mesh_grid_warm": {"warm_s": round(warm_s, 4),
                           "warm_s_all": [round(w, 4) for w in warm],
                           "n_ops": mesh_n_ops,
                           "lanes": len(mesh_grid)},
        "baseline_pr3": PR3_BASELINE,
    }
    if (len(mesh_grid) == PR3_BASELINE["lanes"]
            and mesh_n_ops == PR3_BASELINE["n_ops"]):
        record["mesh_grid_warm"]["improvement_vs_pr3"] = round(
            PR3_BASELINE["warm_s"] / warm_s, 3)
        emit("topology/mesh_grid/improvement_vs_pr3", warm_s * 1e6,
             record["mesh_grid_warm"]["improvement_vs_pr3"])

    os.makedirs(os.path.dirname(JSON_PATH) or ".", exist_ok=True)
    with open(JSON_PATH, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    print(f"# wrote {JSON_PATH}", flush=True)


if __name__ == "__main__":
    run()
