"""Fig. 6: execution time per app, techniques {BNMP, LDB, PEI} x mappers
{B(aseline), TOM, AIMM}, normalized to each technique's baseline."""
from benchmarks.common import apps, cached_episode, emit
from repro.nmp.stats import summarize


def run():
    for app in apps():
        for tech in ("bnmp", "ldb", "pei"):
            base = cached_episode(app, tech, "none")
            bcyc = summarize(base["res"])["cycles"]
            emit(f"fig6/{app}/{tech}/B", base["us"], 1.0)
            for mapper in ("tom", "aimm"):
                r = cached_episode(app, tech, mapper)
                cyc = summarize(r["res"])["cycles"]
                emit(f"fig6/{app}/{tech}/{mapper.upper()}", r["us"],
                     round(cyc / bcyc, 4))


if __name__ == "__main__":
    run()
