"""Fig. 6: execution time per app, techniques {BNMP, LDB, PEI} x mappers
{B(aseline), TOM, AIMM}, normalized to each technique's baseline.

All cells come from the shared batched figure grid (one compiled sweep per
agent mode, see common.figure_grid) instead of per-cell serial episodes."""
from benchmarks.common import apps, emit, figure_grid, grid_us, lane_summary


def run():
    cached = figure_grid()
    us = grid_us(cached)
    for app in apps():
        for tech in ("bnmp", "ldb", "pei"):
            bcyc = lane_summary(cached, f"{app}/{tech}/none/s0")["cycles"]
            emit(f"fig6/{app}/{tech}/B", us, 1.0)
            for mapper in ("tom", "aimm"):
                cyc = lane_summary(cached, f"{app}/{tech}/{mapper}/s0")["cycles"]
                emit(f"fig6/{app}/{tech}/{mapper.upper()}", us,
                     round(cyc / bcyc, 4))


if __name__ == "__main__":
    run()
