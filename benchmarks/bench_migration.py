"""Fig. 10: migration stats — fraction of pages migrated and fraction of
accesses landing on migrated pages (AIMM)."""
from benchmarks.common import apps, cached_episode, emit
from repro.nmp.stats import summarize


def run():
    for app in apps():
        r = cached_episode(app, "bnmp", "aimm")
        s = summarize(r["res"])
        emit(f"fig10/{app}/frac_pages_migrated", r["us"],
             round(s["frac_pages_migrated"], 4))
        emit(f"fig10/{app}/frac_access_on_migrated", r["us"],
             round(s["frac_access_migrated"], 4))


if __name__ == "__main__":
    run()
