"""Fig. 10: migration stats — fraction of pages migrated and fraction of
accesses landing on migrated pages (AIMM).  Served from the shared batched
figure grid (common.figure_grid)."""
from benchmarks.common import apps, emit, figure_grid, grid_us, lane_summary


def run():
    cached = figure_grid()
    us = grid_us(cached)
    for app in apps():
        s = lane_summary(cached, f"{app}/bnmp/aimm/s0")
        emit(f"fig10/{app}/frac_pages_migrated", us,
             round(s["frac_pages_migrated"], 4))
        emit(f"fig10/{app}/frac_access_on_migrated", us,
             round(s["frac_access_migrated"], 4))


if __name__ == "__main__":
    run()
