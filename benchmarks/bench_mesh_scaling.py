"""Mesh scaling along both of the repo's mesh axes.

Fig. 11 (paper): 8x8 *memory-cube* mesh — AIMM adapts to the larger cube
network without retraining hyperparameters (execution time normalized to
8x8 BNMP).  One batched sweep under the 8x8 config covers every app's
baseline + AIMM lane.

Device-mesh shape sweep (fleet axis): the same seed-wide grid timed under
every (lanes x seeds) device-mesh factorization of the visible device
count via REPRO_SWEEP_MESH, plus the auto-factored shape — warm wall,
padded-cell waste (`plan.padding_waste`), and bit-identity vs the auto
shape per point.  The sweep is folded into ``bench_out/BENCH_fleet.json``
under ``device_mesh_sweep`` (read-modify-write, so module order relative
to ``bench_fleet`` does not matter).
"""
import json
import os
import time

from benchmarks.common import (EPISODES, N_OPS, apps, cached_grid, emit,
                               grid_us, lane_summary)
from repro.nmp import NMPConfig

CFG8 = NMPConfig(mesh_x=8, mesh_y=8)

FLEET_JSON = os.environ.get("BENCH_FLEET_JSON", "bench_out/BENCH_fleet.json")
SWEEP_SEEDS = 8
SWEEP_N_OPS = 512
SWEEP_REPS = 3


def _device_mesh_sweep():
    from benchmarks.common import env_overrides, metrics_equal, min_warm
    from repro.nmp import partition
    from repro.nmp import plan as plan_mod
    from repro.nmp.scenarios import single_program_grid
    from repro.nmp.sweep import run_grid

    n_dev = len(partition.sweep_devices())
    grid = single_program_grid(apps=("KM", "SPMV"), mappers=("aimm",),
                               n_ops=SWEEP_N_OPS,
                               seeds=tuple(range(SWEEP_SEEDS)),
                               aimm_episodes=2)
    shapes = [(dl, n_dev // dl) for dl in range(1, n_dev + 1)
              if n_dev % dl == 0]
    with env_overrides(REPRO_SWEEP_MESH=None, REPRO_SEED_SHARE=None):
        auto = run_grid(grid)
    points = []
    for dl, ds in shapes:
        with env_overrides(REPRO_SWEEP_MESH=f"{dl}x{ds}",
                           REPRO_SEED_SHARE=None):
            res = run_grid(grid)            # compile
            def rerun():
                nonlocal res
                res = run_grid(grid)
            warm_s, warm = min_warm(rerun, SWEEP_REPS)
        waste = plan_mod.padding_waste(res.plan, dl, ds)
        ident = metrics_equal(auto, res)
        emit(f"mesh_sweep/{dl}x{ds}/warm_s", warm_s * 1e6,
             round(warm_s, 3))
        points.append({"shape": [dl, ds], "warm_s": round(warm_s, 4),
                       "padding_waste": round(waste, 4),
                       "bit_identical_vs_auto": bool(ident)})
    record = {"device_mesh_sweep": {
        "grid": {"cells": len(grid), "seeds": SWEEP_SEEDS,
                 "n_ops": SWEEP_N_OPS},
        "n_devices": n_dev,
        "auto_shape": list(auto.mesh_shape),
        "points": points,
    }}
    os.makedirs(os.path.dirname(FLEET_JSON) or ".", exist_ok=True)
    existing = {}
    if os.path.exists(FLEET_JSON):
        try:
            with open(FLEET_JSON) as f:
                existing = json.load(f)
        except (OSError, json.JSONDecodeError):
            existing = {}
    existing.update(record)
    with open(FLEET_JSON, "w") as f:
        json.dump(existing, f, indent=2)
        f.write("\n")
    print(f"# wrote {FLEET_JSON} (device_mesh_sweep)", flush=True)


def run():
    cached = cached_grid("single", cfg=CFG8, apps=apps(),
                         techniques=("bnmp",), mappers=("none", "aimm"),
                         n_ops=N_OPS, aimm_episodes=EPISODES,
                         eval_episode=True)
    us = grid_us(cached)
    for app in apps():
        bcyc = lane_summary(cached, f"{app}/bnmp/none/s0")["cycles"]
        cyc = lane_summary(cached, f"{app}/bnmp/aimm/s0")["cycles"]
        emit(f"fig11/{app}/8x8/AIMM_norm_time", us, round(cyc / bcyc, 4))
    _device_mesh_sweep()


if __name__ == "__main__":
    run()
