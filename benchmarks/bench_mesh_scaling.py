"""Fig. 11: 8x8 memory-cube mesh — AIMM adapts to the larger network without
retraining hyperparameters (execution time normalized to 8x8 BNMP).  One
batched sweep under the 8x8 config covers every app's baseline + AIMM lane."""
from benchmarks.common import (EPISODES, N_OPS, apps, cached_grid, emit,
                               grid_us, lane_summary)
from repro.nmp import NMPConfig

CFG8 = NMPConfig(mesh_x=8, mesh_y=8)


def run():
    cached = cached_grid("single", cfg=CFG8, apps=apps(),
                         techniques=("bnmp",), mappers=("none", "aimm"),
                         n_ops=N_OPS, aimm_episodes=EPISODES,
                         eval_episode=True)
    us = grid_us(cached)
    for app in apps():
        bcyc = lane_summary(cached, f"{app}/bnmp/none/s0")["cycles"]
        cyc = lane_summary(cached, f"{app}/bnmp/aimm/s0")["cycles"]
        emit(f"fig11/{app}/8x8/AIMM_norm_time", us, round(cyc / bcyc, 4))


if __name__ == "__main__":
    run()
