"""Fig. 11: 8x8 memory-cube mesh — AIMM adapts to the larger network without
retraining hyperparameters (execution time normalized to 8x8 BNMP)."""
from benchmarks.common import apps, cached_episode, emit
from repro.nmp import NMPConfig
from repro.nmp.stats import summarize

CFG8 = NMPConfig(mesh_x=8, mesh_y=8)


def run():
    for app in apps():
        base = cached_episode(app, "bnmp", "none", cfg=CFG8)
        bcyc = summarize(base["res"])["cycles"]
        r = cached_episode(app, "bnmp", "aimm", cfg=CFG8)
        cyc = summarize(r["res"])["cycles"]
        emit(f"fig11/{app}/8x8/AIMM_norm_time", r["us"],
             round(cyc / bcyc, 4))


if __name__ == "__main__":
    run()
