"""Engine hot-path benchmark: epoch-scan throughput + serial-vs-batched
comparison on the standard 18-lane grid, emitted both as CSV rows and as a
machine-readable ``bench_out/BENCH_engine.json`` so the perf trajectory is
tracked across PRs (see benchmarks/README.md for the schema).

The grid is the same app x mapper x seed sweep bench_workloads historically
timed: {KM, PR, SPMV} x {none, tom, aimm} x seeds {0, 1}, AIMM lanes chained
for 2 (FULL: 3) episodes.  Per-lane metrics are asserted identical between
the batched and serial paths, so the speedup rows are apples-to-apples.

``PRE_PR_BASELINE`` pins the PR 1 engine's wall time for the default grid,
measured on the reference container under quiet conditions (interleaved A/B,
min of 5 warm runs x 3 reps); ``improvement_vs_pre_pr`` is only reported when
the grid matches that measurement's shape.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import FULL, N_OPS, Timer, emit

JSON_PATH = os.environ.get("BENCH_JSON", "bench_out/BENCH_engine.json")

# PR 1 engine, default grid (n_ops=2048, 18 lanes), quiet-machine min-warm.
PRE_PR_BASELINE = {"warm_s": 0.894, "n_ops": 2048, "lanes": 18,
                   "note": "PR 1 engine, same container, interleaved A/B"}


def _grid():
    from repro.nmp.scenarios import single_program_grid
    n_ops = N_OPS // 2 if FULL else N_OPS // 8
    return n_ops, single_program_grid(
        apps=("KM", "PR", "SPMV"), mappers=("none", "tom", "aimm"),
        n_ops=n_ops, seeds=(0, 1), aimm_episodes=3 if FULL else 2)


def run():
    from repro.nmp.sweep import run_grid, run_grid_serial

    n_ops, grid = _grid()
    res = run_grid(grid)                   # wall_s includes build + compile
    cold_s = res.wall_s
    warm = []
    for _ in range(5):
        t0 = time.time()
        res = run_grid(grid)
        warm.append(time.time() - t0)
    warm_s = min(warm)

    with Timer() as t_serial:
        serial = run_grid_serial(grid)
    serial_s = t_serial.us / 1e6

    mismatches = sum(
        1 for i in range(len(grid))
        if serial[i]["cycles"] != res.episode_summary(i)["cycles"])

    # scan steps actually executed: lanes x chained episodes x epoch steps
    lane_epochs = float(np.sum(res.metrics["epochs"]))
    steps_per_s = lane_epochs / warm_s

    tag = f"engine/grid{len(grid)}"
    emit(f"{tag}/batched_cold_s", cold_s * 1e6, round(cold_s, 2))
    emit(f"{tag}/batched_warm_s", warm_s * 1e6, round(warm_s, 3))
    emit(f"{tag}/serial_s", t_serial.us, round(serial_s, 2))
    emit(f"{tag}/speedup_serial_vs_batched", warm_s * 1e6,
         round(serial_s / warm_s, 2))
    emit(f"{tag}/epoch_steps_per_s", warm_s * 1e6, round(steps_per_s, 1))
    emit(f"{tag}/metric_mismatches", warm_s * 1e6, mismatches)
    for i, sc in enumerate(grid):
        if sc.seed == 0:
            emit(f"engine/{sc.name}/opc", warm_s * 1e6 / len(grid),
                 round(res.episode_summary(i)["opc"], 4))

    record = {
        "grid": {"lanes": len(grid), "n_ops": n_ops,
                 "apps": ["KM", "PR", "SPMV"],
                 "mappers": ["none", "tom", "aimm"], "seeds": [0, 1],
                 "aimm_episodes": 3 if FULL else 2, "full": FULL},
        "batched": {"cold_s": round(cold_s, 3),
                    "warm_s": round(warm_s, 4),
                    "warm_s_all": [round(w, 4) for w in warm],
                    "lane_epochs": lane_epochs,
                    "epoch_steps_per_s": round(steps_per_s, 1)},
        "serial": {"wall_s": round(serial_s, 3)},
        "speedup_serial_vs_batched": round(serial_s / warm_s, 3),
        "metric_mismatches": mismatches,
        "baseline_pre_pr": PRE_PR_BASELINE,
    }
    if (n_ops == PRE_PR_BASELINE["n_ops"]
            and len(grid) == PRE_PR_BASELINE["lanes"]):
        record["improvement_vs_pre_pr"] = round(
            PRE_PR_BASELINE["warm_s"] / warm_s, 3)
        emit(f"{tag}/improvement_vs_pre_pr", warm_s * 1e6,
             record["improvement_vs_pre_pr"])

    os.makedirs(os.path.dirname(JSON_PATH) or ".", exist_ok=True)
    with open(JSON_PATH, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    print(f"# wrote {JSON_PATH}", flush=True)


if __name__ == "__main__":
    run()
