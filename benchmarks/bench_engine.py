"""Engine hot-path benchmark: epoch-scan throughput + serial-vs-batched
comparison on the standard 18-cell grid, emitted both as CSV rows and as a
machine-readable ``bench_out/BENCH_engine.json`` so the perf trajectory is
tracked across PRs (see benchmarks/README.md for the schema).

The grid is the same app x mapper x seed sweep bench_workloads historically
timed: {KM, PR, SPMV} x {none, tom, aimm} x seeds {0, 1}, AIMM lanes chained
for 2 (FULL: 3) episodes.  Since PR 3 the sweep runs through the
plan/partition/execute pipeline: the 18 cells fold into 9 lanes with a
2-wide vmapped seed axis, and the lane axis is sharded over the device mesh
when more than one device is visible (forced-host-device CI, real
multi-chip) — the record carries the device count and mesh shape so
throughput numbers are comparable.  Per-cell metrics are asserted identical
between the batched and serial paths, so the speedup rows are
apples-to-apples.

``PRE_PR_BASELINE`` pins the PR 1 engine's wall time for the default grid;
``PR2_BASELINE`` pins the PR 2 single-device engine (pre-pipeline, one lane
per seed) on the same grid.  Both were measured on the reference container
under quiet conditions (interleaved A/B, min of warm runs);
``improvement_vs_*`` fields are only reported when the grid matches that
measurement's shape.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import FULL, N_OPS, Timer, emit

JSON_PATH = os.environ.get("BENCH_JSON", "bench_out/BENCH_engine.json")

# PR 1 engine, default grid (n_ops=2048, 18 cells), quiet-machine min-warm.
PRE_PR_BASELINE = {"warm_s": 0.894, "n_ops": 2048, "lanes": 18,
                   "note": "PR 1 engine, same container, interleaved A/B"}
# PR 2 engine (single device, no seed folding), same grid and protocol.
PR2_BASELINE = {"warm_s": 0.4885, "n_ops": 2048, "lanes": 18,
                "note": "PR 2 single-device engine, same container"}


def _grid():
    from repro.nmp.scenarios import single_program_grid
    n_ops = N_OPS // 2 if FULL else N_OPS // 8
    return n_ops, single_program_grid(
        apps=("KM", "PR", "SPMV"), mappers=("none", "tom", "aimm"),
        n_ops=n_ops, seeds=(0, 1), aimm_episodes=3 if FULL else 2)


def run():
    from repro.nmp import partition
    from repro.nmp.sweep import run_grid, run_grid_serial

    n_ops, grid = _grid()
    res = run_grid(grid)                   # wall_s includes build + compile
    cold_s = res.wall_s
    # min-of-9: the container is 2-core and noisy; the min is the signal
    # (see benchmarks/README.md), and more reps tighten the min estimator.
    warm = []
    for _ in range(9):
        t0 = time.time()
        res = run_grid(grid)
        warm.append(time.time() - t0)
    warm_s = min(warm)

    with Timer() as t_serial:
        serial = run_grid_serial(grid)
    serial_s = t_serial.us / 1e6

    mismatches = sum(
        1 for i in range(len(grid))
        if serial[i]["cycles"] != res.episode_summary(i)["cycles"])

    # Delivered work: cells x chained episodes x epoch steps, summed over the
    # *unfolded* grid — comparable across PRs regardless of how the plan
    # layer folds or collapses seeds.  `executed_epochs` is the deduplicated
    # count (seed-invariant cells simulated once; padded seed slots and
    # device-divisibility padding lanes included), i.e. what the devices
    # actually ran; the gap between the two is the invariant-seed collapse's
    # saving (or, sharded, the padding overhead).
    lane_epochs = float(np.sum(res.metrics["epochs"]))
    mesh_obj = partition.build_mesh()
    executed_epochs = 0.0
    for g in res.plan.groups:
        lane_exec = []
        for lane in g.lanes:
            rep = {}
            for i, s in zip(lane.indices, lane.slots):
                rep.setdefault(s, i)
            per_slot = [float(np.sum(res.metrics["epochs"][i]))
                        for i in rep.values()]
            lane_exec.append(sum(per_slot)
                             + (g.n_seeds - len(per_slot)) * per_slot[0])
        # device-divisibility padding re-simulates lane 0 of the group
        pad_lanes = partition.padded_lane_count(g.n_lanes, mesh_obj) - g.n_lanes
        executed_epochs += sum(lane_exec) + pad_lanes * lane_exec[0]
    steps_per_s = lane_epochs / warm_s
    mesh = partition.mesh_desc(mesh_obj)

    tag = f"engine/grid{len(grid)}"
    emit(f"{tag}/batched_cold_s", cold_s * 1e6, round(cold_s, 2))
    emit(f"{tag}/batched_warm_s", warm_s * 1e6, round(warm_s, 3))
    emit(f"{tag}/serial_s", t_serial.us, round(serial_s, 2))
    emit(f"{tag}/speedup_serial_vs_batched", warm_s * 1e6,
         round(serial_s / warm_s, 2))
    emit(f"{tag}/epoch_steps_per_s", warm_s * 1e6, round(steps_per_s, 1))
    emit(f"{tag}/metric_mismatches", warm_s * 1e6, mismatches)
    emit(f"{tag}/n_devices", warm_s * 1e6, mesh["n_devices"])
    for i, sc in enumerate(grid):
        if sc.seed == 0:
            emit(f"engine/{sc.name}/opc", warm_s * 1e6 / len(grid),
                 round(res.episode_summary(i)["opc"], 4))
            band = res.variance_band(i)
            emit(f"engine/{sc.name}/opc_band", warm_s * 1e6 / len(grid),
                 f"{band['opc_mean']:.4f}±{band['opc_std']:.4f}(n={band['n']})")

    record = {
        "grid": {"lanes": len(grid), "n_ops": n_ops,
                 "apps": ["KM", "PR", "SPMV"],
                 "mappers": ["none", "tom", "aimm"], "seeds": [0, 1],
                 "aimm_episodes": 3 if FULL else 2, "full": FULL,
                 "folded_lanes": res.plan.n_lanes,
                 "seed_axis": [g.n_seeds for g in res.plan.groups]},
        "mesh": {**mesh, "sharded": mesh["n_devices"] > 1},
        "batched": {"cold_s": round(cold_s, 3),
                    "warm_s": round(warm_s, 4),
                    "warm_s_all": [round(w, 4) for w in warm],
                    "lane_epochs": lane_epochs,
                    "executed_epochs": executed_epochs,
                    "epoch_steps_per_s": round(steps_per_s, 1),
                    "n_devices": mesh["n_devices"]},
        "serial": {"wall_s": round(serial_s, 3)},
        "speedup_serial_vs_batched": round(serial_s / warm_s, 3),
        "metric_mismatches": mismatches,
        "baseline_pre_pr": PRE_PR_BASELINE,
        "baseline_pr2_single_device": PR2_BASELINE,
    }
    if (n_ops == PRE_PR_BASELINE["n_ops"]
            and len(grid) == PRE_PR_BASELINE["lanes"]):
        record["improvement_vs_pre_pr"] = round(
            PRE_PR_BASELINE["warm_s"] / warm_s, 3)
        record["improvement_vs_pr2_single_device"] = round(
            PR2_BASELINE["warm_s"] / warm_s, 3)
        emit(f"{tag}/improvement_vs_pre_pr", warm_s * 1e6,
             record["improvement_vs_pre_pr"])
        emit(f"{tag}/improvement_vs_pr2_single_device", warm_s * 1e6,
             record["improvement_vs_pr2_single_device"])

    os.makedirs(os.path.dirname(JSON_PATH) or ".", exist_ok=True)
    with open(JSON_PATH, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    print(f"# wrote {JSON_PATH}", flush=True)


if __name__ == "__main__":
    run()
