"""§Roofline summary from the dry-run artifact (results/dryrun.json).

Reports, per compiled (arch x shape x mesh) cell: the three roofline terms,
the dominant bottleneck, and the roofline fraction. Requires the dry-run to
have been produced (python -m repro.launch.dryrun --all)."""
import json
import os

from benchmarks.common import emit

DRYRUN = os.environ.get("DRYRUN_JSON", "results/dryrun.json")


def run():
    if not os.path.exists(DRYRUN):
        emit("roofline/missing", 0.0, f"run repro.launch.dryrun first")
        return
    with open(DRYRUN) as f:
        data = json.load(f)
    for key, v in sorted(data.items()):
        if v.get("status") != "ok":
            continue
        r = v["roofline"]
        name = key.replace("|", "/")
        us = v.get("compile_s", 0.0) * 1e6
        emit(f"roofline/{name}/dominant", us, r["dominant"])
        emit(f"roofline/{name}/step_ms", us,
             round(max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e3,
                   3))
        emit(f"roofline/{name}/fraction", us,
             round(r["roofline_fraction"], 4))


if __name__ == "__main__":
    run()
