"""Fig. 13: sensitivity to page-info-cache entries and NMP-op table size
(representative apps PR, SPMV per the paper)."""
import dataclasses

from benchmarks.common import Timer, cached_episode, emit, EPISODES, N_OPS
from repro.nmp import NMPConfig, make_trace, run_program
from repro.nmp.stats import summarize


def run():
    for app in ("PR", "SPMV"):
        tr = make_trace(app, n_ops=N_OPS)
        for entries in (32, 64, 128, 256):
            cfg = NMPConfig(page_cache_entries=entries)
            with Timer() as t:
                results = run_program(tr, cfg, "bnmp", "aimm",
                                      episodes=EPISODES, seed=0)
            emit(f"fig13/{app}/page_cache_E{entries}", t.us,
                 round(summarize(results[-1])["cycles"], 1))
        for table in (32, 64, 128, 512):
            cfg = NMPConfig(nmp_table_size=table)
            with Timer() as t:
                results = run_program(tr, cfg, "bnmp", "aimm",
                                      episodes=EPISODES, seed=0)
            emit(f"fig13/{app}/nmp_table_E{table}", t.us,
                 round(summarize(results[-1])["cycles"], 1))


if __name__ == "__main__":
    run()
