"""Fig. 13: sensitivity to page-info-cache entries and NMP-op table size
(representative apps PR, SPMV per the paper).  Each config point runs both
apps' AIMM lanes through one batched sweep (cfg is part of the grid cache
key, so every point is exactly one compile + dispatch)."""
from benchmarks.common import EPISODES, N_OPS, cached_grid, emit, grid_us, lane_summary
from repro.nmp import NMPConfig

SWEEP_APPS = ("PR", "SPMV")


def _point(cfg, tag: str) -> None:
    cached = cached_grid("single", cfg=cfg, apps=SWEEP_APPS,
                         techniques=("bnmp",), mappers=("aimm",),
                         n_ops=N_OPS, aimm_episodes=EPISODES)
    us = grid_us(cached)
    for app in SWEEP_APPS:
        s = lane_summary(cached, f"{app}/bnmp/aimm/s0")
        emit(f"fig13/{app}/{tag}", us, round(s["cycles"], 1))


def run():
    for entries in (32, 64, 128, 256):
        _point(NMPConfig(page_cache_entries=entries), f"page_cache_E{entries}")
    for table in (32, 64, 128, 512):
        _point(NMPConfig(nmp_table_size=table), f"nmp_table_E{table}")


if __name__ == "__main__":
    run()
