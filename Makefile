# Single entry point for CI / local development.
#
#   make test         tier-1 verify: the full suite (what the roadmap gates on)
#   make test-fast    quick lane: skips tests marked `slow`
#   make test-4dev    test-fast on a forced 4-device host platform (the sweep
#                     partition layer shards every grid over a 4-wide mesh,
#                     and the serving tests multiplex tenants over slot-
#                     sharded resident programs)
#   make test-faults  the fault-injection suite (tests/test_faults.py) on the
#                     default platform AND the forced 4-device platform —
#                     tenant quarantine/rollback isolation, crash-safe
#                     checkpoint durability (kill-resume), shrink-devices
#   make test-fleet   the fleet-scale suite (tests/test_fleet.py): 2-D mesh
#                     bit-identity across shapes (forced 4-device subprocess),
#                     seed-share on/off equivalence, shard packing, and the
#                     2-local-process jax.distributed scaffolding
#   make test-pallas  the Pallas parity suite (tests/test_pallas_parity.py):
#                     fused epoch kernel + dueling-qnet kernel in interpret
#                     mode on CPU, pinned bit-identical against the jnp path
#                     and the engine goldens, plus the async-landing /
#                     agent-staging equivalence checks
#   make bench-smoke  smallest benchmark slice (fig5 + the engine perf record
#                     + the continual warm-vs-cold record + the multi-tenant
#                     serving record + the fault-tolerance record + the
#                     topology-axis record + the fleet-scale record + the
#                     epoch-kernel record: writes bench_out/BENCH_engine.json,
#                     BENCH_continual.json, BENCH_serving.json,
#                     BENCH_faults.json, BENCH_topology.json,
#                     BENCH_fleet.json and BENCH_epoch_kernel.json)
#   make bench-continual  just the continual-stream warm-vs-cold benchmark
#   make bench-serving    just the multi-tenant serving benchmark (64 tenant
#                         streams through 16 resident slot programs)
#   make bench-faults     just the fault-tolerance benchmark (recovery drills
#                         + the divergence guard's no-fault overhead)
#   make bench-topology   just the topology-axis benchmark (per-interconnect
#                         learned-AIMM vs baseline + mesh warm-grid guard)
#   make bench-epoch      just the epoch-kernel benchmark (fused backend +
#                         async landing + agent staging vs PR 8 emulation)
#   make bench        every benchmark figure (BENCH_FULL=1 for paper scale)
#   make profile      JAX profiler trace of one batched grid -> bench_out/profile

PY ?= python
# src for the repro package, repo root for the benchmarks package
PYTHONPATH := src:.$(if $(PYTHONPATH),:$(PYTHONPATH),)
export PYTHONPATH

.PHONY: test test-fast test-4dev test-faults test-fleet test-pallas \
	bench-smoke bench-continual bench-serving bench-faults bench-topology \
	bench-fleet bench-epoch bench profile

test:
	$(PY) -m pytest -x -q

test-fast:
	$(PY) -m pytest -x -q -m "not slow"

# Forced 4-device host platform: the whole fast lane sharded, including the
# topology equivalence tests (tests/test_topology.py runs the mixed-topology
# grid against serial per-lane runs on the 4-wide lane mesh).
test-4dev:
	XLA_FLAGS="--xla_force_host_platform_device_count=4 $$XLA_FLAGS" \
	JAX_PLATFORMS=cpu $(PY) -m pytest -x -q -m "not slow"

# The fault-injection suite on both platforms: single-device and a forced
# 4-device host (the quarantine/rollback isolation and the shrink-devices
# re-mesh path are only fully exercised when lanes are device-sharded).
test-faults:
	$(PY) -m pytest -x -q tests/test_faults.py
	XLA_FLAGS="--xla_force_host_platform_device_count=4 $$XLA_FLAGS" \
	JAX_PLATFORMS=cpu $(PY) -m pytest -x -q tests/test_faults.py

# Fleet-scale suite: includes the slow forced-4-device and 2-process
# subprocess tests regardless of the parent platform.
test-fleet:
	$(PY) -m pytest -x -q tests/test_fleet.py

# Pallas parity suite: the fused epoch kernel and the dueling-qnet kernel in
# interpret mode on CPU, pinned against the jnp reference path and the
# engine goldens (BodyFlags on/off, S==1 vs S>1, knob validation, and the
# async-landing / agent-staging bit-identity checks ride along).
test-pallas:
	$(PY) -m pytest -x -q tests/test_pallas_parity.py

bench-smoke:
	BENCH_ONLY=fig5,engine,continual,serving,faults,topology,fleet,epoch_kernel $(PY) benchmarks/run.py

bench-continual:
	BENCH_ONLY=continual $(PY) benchmarks/run.py

bench-serving:
	BENCH_ONLY=serving $(PY) benchmarks/run.py

bench-faults:
	BENCH_ONLY=faults $(PY) benchmarks/run.py

bench-topology:
	BENCH_ONLY=topology $(PY) benchmarks/run.py

bench-fleet:
	BENCH_ONLY=fleet $(PY) benchmarks/run.py

bench-epoch:
	BENCH_ONLY=epoch_kernel $(PY) benchmarks/run.py

bench:
	$(PY) benchmarks/run.py

profile:
	$(PY) benchmarks/profile_grid.py
